"""Property tests for the finite-field layer (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.field import (
    FIELD31,
    FIELD_WIDE,
    crt_combine_signed,
    fadd,
    finv_host,
    fmul,
    fneg,
    fsub,
    lift_signed,
    random_elements,
)

FIELDS = [FIELD31, FIELD_WIDE]


def elems(field, values):
    """Lift python ints to (R, n) reduced field elements."""
    return lift_signed(jnp.asarray(values, dtype=jnp.int64), field)


@pytest.mark.parametrize("field", FIELDS, ids=lambda f: f.name)
@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_field_ring_axioms(field, data):
    n = 4
    lim = field.moduli[0] - 1
    a = data.draw(st.lists(st.integers(0, lim), min_size=n, max_size=n))
    b = data.draw(st.lists(st.integers(0, lim), min_size=n, max_size=n))
    c = data.draw(st.lists(st.integers(0, lim), min_size=n, max_size=n))
    fa, fb, fc = elems(field, a), elems(field, b), elems(field, c)
    # commutativity / associativity / distributivity
    assert (fadd(fa, fb, field) == fadd(fb, fa, field)).all()
    assert (fmul(fa, fb, field) == fmul(fb, fa, field)).all()
    lhs = fmul(fa, fadd(fb, fc, field), field)
    rhs = fadd(fmul(fa, fb, field), fmul(fa, fc, field), field)
    assert (lhs == rhs).all()
    # additive inverse
    zero = jnp.zeros_like(fa)
    assert (fadd(fa, fneg(fa, field), field) == zero).all()
    assert (fsub(fa, fa, field) == zero).all()


@pytest.mark.parametrize("field", FIELDS, ids=lambda f: f.name)
@given(v=st.integers(-(2**40), 2**40))
@settings(max_examples=50, deadline=None)
def test_signed_lift_roundtrip(field, v):
    if abs(v) > field.max_signed:
        v = v % field.max_signed
    arr = jnp.asarray([v], dtype=jnp.int64)
    back = crt_combine_signed(lift_signed(arr, field), field)
    assert int(back[0]) == int(arr[0])


def test_crt_range_is_wide():
    # the CRT pair must cover Hessian-scale aggregates: 1e6 records of
    # magnitude 1e6 at 2**20 fixed-point scale
    assert FIELD_WIDE.max_signed > 1e6 * 1e6 * 2**20 / 2  # ~5.5e17 < 2.3e18


def test_finv_host():
    for p in FIELD_WIDE.moduli:
        for x in (1, 2, 12345, p - 1):
            assert (x * finv_host(x, p)) % p == 1
    with pytest.raises(ZeroDivisionError):
        finv_host(0, FIELD31.moduli[0])


def test_random_elements_reduced_and_spread(rng_key):
    x = random_elements(rng_key, (4096,), FIELD_WIDE)
    assert x.shape == (2, 4096)
    p = np.asarray(FIELD_WIDE.moduli, dtype=np.uint64)[:, None]
    assert (np.asarray(x) < p).all()
    # crude uniformity check: mean near p/2 within 5%
    means = np.asarray(x, dtype=np.float64).mean(axis=1)
    assert np.allclose(means, p[:, 0] / 2, rtol=0.05)
