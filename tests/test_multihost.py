"""Multi-host SPMD rounds: 2D (pod, share) mesh, sharded-tile aggregates,
and the XLA flag/knob plumbing that gets a CPU mesh up in CI.

The mesh tests run in a subprocess because XLA_FLAGS must be owned before
jax initializes (same constraint ``distributed.xla_flags`` encodes); the
flag-builder and kernel-knob tests are plain host-side unit tests.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.xla_flags import (
    LATENCY_HIDING_FLAGS,
    _merge_flags,
    apply_xla_flags,
    jax_backend_initialized,
    mesh_env,
)
from repro.kernels.tuning import (
    DEFAULT_KNOBS,
    KernelKnobs,
    validate_real_kernel_knobs,
    vmem_bytes,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------ xla_flags

def test_merge_flags_last_writer_wins_per_flag():
    merged = _merge_flags(
        "--xla_force_host_platform_device_count=3 --a=1",
        ["--xla_force_host_platform_device_count=8", "--b=2"],
    )
    assert merged == ("--xla_force_host_platform_device_count=8 "
                      "--a=1 --b=2")


def test_mesh_env_builds_child_flags_without_touching_parent():
    before = os.environ.get("XLA_FLAGS")
    env = mesh_env(host_device_count=6, base={"PATH": "/bin"})
    assert env["XLA_FLAGS"] == "--xla_force_host_platform_device_count=6"
    assert env["PATH"] == "/bin"
    assert os.environ.get("XLA_FLAGS") == before


def test_mesh_env_latency_hiding_is_gpu_only_opt_in():
    """The GPU collective-overlap flags appear only on request: XLA
    hard-aborts on unknown flags, and CPU builds do not register the
    --xla_gpu_* family, so CPU-mesh children must never inherit them."""
    plain = mesh_env(host_device_count=4, base={})
    assert "--xla_gpu_" not in plain["XLA_FLAGS"]
    gpu = mesh_env(host_device_count=4, latency_hiding=True, base={})
    for flag in LATENCY_HIDING_FLAGS:
        assert flag in gpu["XLA_FLAGS"]


def test_apply_xla_flags_refuses_post_init_changes():
    """This test session has a live jax backend, so any CHANGE must
    raise; re-applying the current value stays idempotent."""
    import jax

    jax.devices()
    assert jax_backend_initialized()
    current = os.environ.get("XLA_FLAGS", "")
    assert apply_xla_flags() == current
    with pytest.raises(RuntimeError, match="already initialized"):
        apply_xla_flags(extra=("--xla_definitely_not_set_yet=1",))
    assert os.environ.get("XLA_FLAGS", "") == current


def test_initialize_distributed_noop_outside_multiprocess(monkeypatch):
    from repro.distributed.multihost import initialize_distributed

    monkeypatch.delenv("JAX_NUM_PROCESSES", raising=False)
    monkeypatch.delenv("JAX_COORDINATOR_ADDRESS", raising=False)
    assert initialize_distributed() is False
    monkeypatch.setenv("JAX_NUM_PROCESSES", "1")
    assert initialize_distributed() is False


# ------------------------------------------------------- kernel knobs

def test_default_knobs_validate_under_vmem_limit():
    reports = validate_real_kernel_knobs()
    assert {r["kernel"] for r in reports} == set(DEFAULT_KNOBS)
    assert all(r["ok"] for r in reports)
    assert all(r["vmem_bytes"] <= r["vmem_limit_bytes"] for r in reports)


def test_knob_validation_rejects_misaligned_and_oversized():
    bad = dict(DEFAULT_KNOBS)
    bad["fused_irls"] = bad["fused_irls"].replace(block_n=500)
    with pytest.raises(ValueError, match="sublane"):
        validate_real_kernel_knobs(bad)
    huge = dict(DEFAULT_KNOBS)
    huge["fused_irls"] = huge["fused_irls"].replace(block_n=1 << 20)
    with pytest.raises(ValueError, match="VMEM"):
        validate_real_kernel_knobs(huge)
    with pytest.raises(ValueError, match="128"):
        validate_real_kernel_knobs(d=100)


def test_vmem_model_monotone_in_block_size():
    small = vmem_bytes(KernelKnobs("fused_irls", block_n=256))
    big = vmem_bytes(KernelKnobs("fused_irls", block_n=1024))
    assert small < big


# ------------------------------------------------- CPU-mesh subprocess

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.secure_agg import SecureAggregator, secure_psum
    from repro.core.flatbuf import pack_pytree, unpack_pytree_tile
    from repro.distributed.compat import shard_map
    from repro.distributed.multihost import (
        pod_mesh, pod_share_mesh, secure_psum_2d, run_scanned_rounds)
    from repro.distributed.sharding import POD_AXIS

    tree = {
        "g": 0.5 * jax.random.normal(jax.random.PRNGKey(1), (300,),
                                     jnp.float32),
        "h": jnp.float32(3.25) * jnp.ones((4, 4), jnp.float32),
    }
    agg = SecureAggregator(backend="pallas")

    # out="tile" keeps the decoded aggregate sharded; gather must equal
    # the replicated out="tree" decode bitwise on an uneven (D=3) mesh.
    D = 3
    mesh = pod_mesh(D)
    tree_out = shard_map(
        lambda: secure_psum(tree, POD_AXIS, jax.random.PRNGKey(5),
                            aggregator=agg, reveal="sharded"),
        mesh=mesh, in_specs=(), out_specs=P(), check_vma=False)()
    tile_out = shard_map(
        lambda: secure_psum(tree, POD_AXIS, jax.random.PRNGKey(5),
                            aggregator=agg, reveal="sharded", out="tile"
                            ).gather(POD_AXIS),
        mesh=mesh, in_specs=(), out_specs=P(), check_vma=False)()
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree_out[k]),
                                      np.asarray(tile_out[k]))
        np.testing.assert_allclose(np.asarray(tile_out[k]),
                                   D * np.asarray(tree[k]), atol=1e-5)

    # host-side tile addressing: per-tile fragments re-assemble the tree
    buf, layout = pack_pytree(tree, row_align=24)  # lcm(8, 3)
    flat_ref = np.concatenate([np.ravel(np.asarray(tree["g"])),
                               np.ravel(np.asarray(tree["h"]))])
    tiles = np.asarray(buf).reshape(3, -1)
    for t in range(3):
        frags = unpack_pytree_tile(
            jnp.asarray(tiles[t].reshape(-1, 128)), layout, t, 3)
        for leaf, (a, b, frag) in frags.items():
            base = 0 if leaf == 0 else tree["g"].size
            np.testing.assert_allclose(np.asarray(frag),
                                       flat_ref[base + a: base + b],
                                       atol=1e-6)

    # 2D (pod, share) mesh: the distributed Lagrange reveal (share slice
    # x public weight, psum over the share axis) must equal the 1D wire
    # bitwise -- same sharing polynomials, same field reconstruction.
    mesh2 = pod_share_mesh(3, agg.scheme.threshold)
    out2 = shard_map(
        lambda: secure_psum_2d(tree, jax.random.PRNGKey(5),
                               aggregator=agg),
        mesh=mesh2, in_specs=(), out_specs=P(), check_vma=False)()
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree_out[k]),
                                      np.asarray(out2[k]))

    # scanned rounds: protect -> aggregate -> reveal chained in-graph is
    # mean-preserving round over round (reveal of round r feeds r+1)
    for reveal in ("replicated", "sharded"):
        final, trace = run_scanned_rounds(
            3, tree, jax.random.PRNGKey(7), 4, aggregator=agg,
            reveal=reveal)
        for k in tree:
            np.testing.assert_allclose(np.asarray(final[k]),
                                       np.asarray(tree[k]), atol=1e-4)
        assert trace.shape == (4,)
    print("MULTIHOST_MESH_OK")
""")


def test_multihost_cpu_mesh(tmp_path):
    """6 forced host devices: sharded-tile parity, 2D distributed reveal
    bitwise vs the 1D wire, and the in-graph scanned round chain."""
    script = tmp_path / "multihost_mesh.py"
    script.write_text(_MESH_SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MULTIHOST_MESH_OK" in r.stdout
