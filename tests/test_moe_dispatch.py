"""MoE capacity dispatch vs dense (every-expert) oracle.

With capacity high enough that nothing drops, the gathered/scattered
dispatch must equal running every expert on every token and mixing by the
(renormalized) top-k gates.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.distributed import MeshRules
from repro.models.moe import _route, moe_ffn


def _dense_oracle(x, params, cfg):
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d).astype(jnp.float32)
    gates, experts, _ = _route(xt, params["router"], cfg.moe_top_k)
    w1 = params["experts_w1"].astype(jnp.float32)
    w3 = params["experts_w3"].astype(jnp.float32)
    w2 = params["experts_w2"].astype(jnp.float32)
    h = jnp.einsum("td,edh->teh", xt, w1)
    g = jax.nn.silu(jnp.einsum("td,edh->teh", xt, w3))
    all_out = jnp.einsum("teh,ehd->ted", h * g, w2)  # (T, E, d)
    onek = jax.nn.one_hot(experts, cfg.moe_num_experts,
                          dtype=jnp.float32)  # (T, k, E)
    mix = jnp.einsum("tke,tk->te", onek, gates)
    y = jnp.einsum("ted,te->td", all_out, mix)
    if "shared_w1" in params:
        sh = jnp.einsum("td,dh->th", xt,
                        params["shared_w1"].astype(jnp.float32))
        sg = jax.nn.silu(jnp.einsum(
            "td,dh->th", xt, params["shared_w3"].astype(jnp.float32)))
        y = y + jnp.einsum("th,hd->td", sh * sg,
                           params["shared_w2"].astype(jnp.float32))
    return y.reshape(B, S, d)


def test_moe_dispatch_matches_dense_oracle(rng_key):
    cfg = smoke_config("qwen3_moe_235b")
    cfg = dataclasses.replace(cfg, capacity_factor=64.0)  # no drops
    d, E, h = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    ks = jax.random.split(rng_key, 5)
    params = {
        "router": 0.5 * jax.random.normal(ks[0], (d, E), jnp.float32),
        "experts_w1": 0.1 * jax.random.normal(ks[1], (E, d, h)),
        "experts_w3": 0.1 * jax.random.normal(ks[2], (E, d, h)),
        "experts_w2": 0.1 * jax.random.normal(ks[3], (E, h, d)),
    }
    x = jax.random.normal(ks[4], (2, 8, d), jnp.float32)
    y, aux, drop = moe_ffn(x, params, cfg, MeshRules(mesh=None))
    gold = _dense_oracle(x, params, cfg)
    assert float(drop) == 0.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(gold),
                               rtol=2e-4, atol=2e-4)


def test_moe_dispatch_drops_over_capacity(rng_key):
    cfg = smoke_config("deepseek_v2_lite")
    cfg = dataclasses.replace(cfg, capacity_factor=0.05)
    d, E, h = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    ks = jax.random.split(rng_key, 7)
    params = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32),
        "experts_w1": 0.1 * jax.random.normal(ks[1], (E, d, h)),
        "experts_w3": 0.1 * jax.random.normal(ks[2], (E, d, h)),
        "experts_w2": 0.1 * jax.random.normal(ks[3], (E, h, d)),
        "shared_w1": 0.1 * jax.random.normal(ks[4], (d, h)),
        "shared_w3": 0.1 * jax.random.normal(ks[5], (d, h)),
        "shared_w2": 0.1 * jax.random.normal(ks[6], (h, d)),
    }
    x = jax.random.normal(ks[0], (2, 16, d), jnp.float32)
    y, aux, drop = moe_ffn(x, params, cfg, MeshRules(mesh=None))
    assert float(drop) > 0.0  # capacity bound is enforced
    assert np.all(np.isfinite(np.asarray(y)))
