"""Whole-fit scan residency: the scanned drivers vs the per-round oracles.

The load-bearing claim (``core/scanfit.py``): revealed aggregates are
exactly rng-independent — Shamir reconstruction cancels the sharing
polynomials in the field — so the scanned round graph (one in-graph
``fold_in`` rng stream, one host sync per block) must reproduce the
per-round drivers BIT-identically on the f64 rung, and within fixed-point
quantization on the f32-Gram rungs.  Block cutting and mid-scan
``state_dict`` resume must be invisible: the slot counter advances on
skipped slots too, so executed round r always folds ``(key, r)``.
"""
import jax
import numpy as np
import pytest

from repro.core import (
    Institution,
    SecureAggregator,
    SecureFitDriver,
    StudyCoordinator,
    secure_fit,
)
from repro.data import generate_synthetic
from repro.runtime import FailureInjector, FaultPolicy, RoundSupervisor

NUM_INST = 4


@pytest.fixture(scope="module")
def study():
    return generate_synthetic(
        jax.random.PRNGKey(3), num_institutions=NUM_INST,
        records_per_institution=150, dim=5,
    )


@pytest.fixture(scope="module")
def agg():
    return SecureAggregator(backend="pallas")


def quant_tol(agg):
    return (NUM_INST + 1) / agg.codec.scale


# --------------------------------------------------------- driver lockstep

@pytest.mark.parametrize("protect", ["none", "gradient", "both"])
def test_scan_fit_matches_per_round_oracle(study, agg, protect):
    """scan == per-round fused: same round count, convergence flag, and
    beta/trace — bitwise, because the revealed aggregates do not depend
    on the rng scheme (host split vs in-graph fold_in)."""
    ref = secure_fit(study.parts, lam=1.0, protect=protect,
                     aggregator=agg, fused=True)
    scan = secure_fit(study.parts, lam=1.0, protect=protect,
                      aggregator=agg, fused=True, rounds="scan")
    assert scan.iterations == ref.iterations
    assert scan.converged == ref.converged
    np.testing.assert_array_equal(np.asarray(scan.beta),
                                  np.asarray(ref.beta))
    assert scan.deviance_trace == ref.deviance_trace


@pytest.mark.parametrize("backend", ["reference", "mixed", "pallas"])
def test_scan_fit_precision_rungs(study, agg, backend):
    """Every summaries rung: the scanned fit tracks the per-round fit at
    the SAME rung.  f64 reference is bit-exact per round; the f32-Gram
    rungs are converged-beta-parity (quantization tolerance), matching
    the rung contract of the per-round drivers."""
    kw = dict(lam=1.0, protect="both", aggregator=agg, fused=True,
              summaries_backend=backend)
    ref = secure_fit(study.parts, **kw)
    scan = secure_fit(study.parts, rounds="scan", **kw)
    assert scan.iterations == ref.iterations
    err = np.abs(np.asarray(scan.beta) - np.asarray(ref.beta)).max()
    if backend == "reference":
        assert err == 0.0
    else:
        assert err <= quant_tol(agg)


def test_blocked_scan_bit_identical_to_whole_fit(study, agg):
    """Cutting the fit into rounds_per_sync blocks must not move a bit:
    the rng fold of executed round r is (key, r) under any block size."""
    whole = secure_fit(study.parts, lam=1.0, protect="both",
                       aggregator=agg, fused=True, rounds="scan")
    for block in (1, 2, 3):
        cut = secure_fit(study.parts, lam=1.0, protect="both",
                         aggregator=agg, fused=True, rounds="scan",
                         rounds_per_sync=block)
        np.testing.assert_array_equal(np.asarray(cut.beta),
                                      np.asarray(whole.beta))
        assert cut.deviance_trace == whole.deviance_trace
        assert cut.iterations == whole.iterations


def test_mid_scan_state_dict_resume_bit_identical(study, agg):
    """Save after one scan block, restore into a FRESH driver, finish:
    beta and trace equal the uninterrupted run exactly."""
    def make():
        return SecureFitDriver(study.parts, lam=1.0, protect="both",
                               aggregator=agg, fused=True, rounds="scan",
                               rounds_per_sync=2)

    d1 = make()
    d1.step_block()
    saved = d1.state_dict()
    d1.run()

    d2 = make()
    d2.load_state_dict(saved)
    d2.run()
    np.testing.assert_array_equal(np.asarray(d1.beta), np.asarray(d2.beta))
    assert d1.trace == d2.trace
    assert d1.iteration == d2.iteration


def test_scan_requires_fused_and_validates_block(study, agg):
    with pytest.raises(ValueError, match="fused"):
        SecureFitDriver(study.parts, lam=1.0, fused=False, rounds="scan")
    with pytest.raises(ValueError, match="rounds"):
        SecureFitDriver(study.parts, lam=1.0, fused=True,
                        aggregator=agg, rounds="sscan")
    with pytest.raises(ValueError, match="rounds_per_sync"):
        SecureFitDriver(study.parts, lam=1.0, fused=True, aggregator=agg,
                        rounds="scan", rounds_per_sync=0)


# ------------------------------------------------------- coordinator path

def _make_coordinator(study, agg, **kw):
    insts = [Institution(f"i{j}", X, y)
             for j, (X, y) in enumerate(study.parts)]
    return StudyCoordinator(insts, lam=1.0, protect="both",
                            aggregator=agg, seed=0, fused=True, **kw)


def test_coordinator_scan_matches_per_round(study, agg):
    """StudyCoordinator(rounds="scan"): same rounds, one report per
    executed round with the per-round byte accounting, bit-equal beta."""
    ref = _make_coordinator(study, agg)
    ref.run()
    scan = _make_coordinator(study, agg, rounds="scan")
    scan.run()
    assert scan.iteration == ref.iteration
    assert len(scan.reports) == scan.iteration
    np.testing.assert_array_equal(np.asarray(scan.beta),
                                  np.asarray(ref.beta))
    for a, b in zip(ref.reports, scan.reports):
        assert a.bytes_transmitted == b.bytes_transmitted
        assert a.responders == b.responders
        assert a.centers_used == b.centers_used


# ------------------------------------------------- supervised scan blocks

def test_supervised_scan_blocks_match_fault_free_oracle(study, agg):
    """A supervised scan-mode fit with a center dying INSIDE a scan block
    (midround hook at block dispatch) converges to the fault-free
    per-round oracle bitwise — any >= t reveal points reconstruct the
    same field element, whole-block or per-round."""
    oracle = secure_fit(study.parts, lam=1.0, protect="both",
                        aggregator=agg, fused=True)

    def make_scan_driver():
        return SecureFitDriver(
            study.parts, lam=1.0, protect="both", aggregator=agg,
            names=[f"i{j}" for j in range(NUM_INST)],
            fused=True, rounds="scan", rounds_per_sync=2,
        )

    drv = make_scan_driver()
    sup = RoundSupervisor(
        drv, policy=FaultPolicy(max_retries=4),
        injector=FailureInjector({
            1: [("center_midround", 1)],
            2: [("center_crash", 2)], 3: [("center_recover", 2)],
        }),
    )
    sup.run(max_rounds=40)
    assert drv.converged
    np.testing.assert_array_equal(np.asarray(drv.beta),
                                  np.asarray(oracle.beta))

    # supervisor retry re-enters at the failed block: crash a center
    # below quorum mid-schedule and let it recover; the fit still lands
    drv2 = make_scan_driver()
    sup2 = RoundSupervisor(
        drv2, policy=FaultPolicy(max_retries=6),
        injector=FailureInjector({
            2: [("center_crash", 1), ("center_crash", 2)],
            3: [("center_recover", 1), ("center_recover", 2)],
        }),
    )
    sup2.run(max_rounds=40)
    assert drv2.converged
    err = np.abs(np.asarray(drv2.beta) - np.asarray(oracle.beta)).max()
    assert err <= quant_tol(agg)
