"""Chunked (GLA-form) RWKV6 recurrence vs the per-token oracle.

The chunked path is the §Perf variant for the rwkv6 train/prefill cells —
it must match the per-token scan exactly (same math, reassociated), for
any decay magnitude (the exact pairwise intra-chunk form has no clamped
approximation on the causal half), for ragged chunk tails, through the
carried state, and in gradients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import _rwkv6_chunked, _rwkv6_recurrence


def _inputs(key, B=2, S=48, H=3, D=8, w_lo=0.3, w_hi=0.999):
    ks = jax.random.split(key, 6)
    f = lambda k: jax.random.normal(k, (B, S, H, D), jnp.float32)
    r, k, v = f(ks[0]), f(ks[1]), f(ks[2])
    w = jax.random.uniform(ks[3], (B, S, H, D), jnp.float32, w_lo, w_hi)
    u = jax.random.normal(ks[4], (H, D), jnp.float32) * 0.5
    s0 = jax.random.normal(ks[5], (B, H, D, D), jnp.float32) * 0.3
    return r, k, v, w, u, s0


@pytest.mark.parametrize("S,chunk", [(48, 16), (64, 16), (50, 16), (7, 16),
                                     (48, 8)])
def test_chunked_matches_per_token(rng_key, S, chunk):
    r, k, v, w, u, s0 = _inputs(rng_key, S=S)
    o_ref, s_ref = _rwkv6_recurrence(r, k, v, w, u, s0)
    o_chk, s_chk = _rwkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(o_chk, o_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(s_chk, s_ref, rtol=2e-5, atol=2e-5)


def test_chunked_strong_decay_exact(rng_key):
    """Fast-decay channels (w -> 1e-6): the overflow-prone regime for
    factored GLA; the exact pairwise form must still match."""
    r, k, v, w, u, s0 = _inputs(rng_key, S=64, w_lo=1e-6, w_hi=1.0)
    o_ref, s_ref = _rwkv6_recurrence(r, k, v, w, u, s0)
    o_chk, s_chk = _rwkv6_chunked(r, k, v, w, u, s0, chunk=16)
    np.testing.assert_allclose(o_chk, o_ref, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(s_chk, s_ref, rtol=3e-5, atol=3e-5)
    assert np.all(np.isfinite(np.asarray(o_chk)))


def test_chunked_gradients_match(rng_key):
    r, k, v, w, u, s0 = _inputs(rng_key, S=32, B=1, H=2, D=6)

    def loss(fn, args):
        o, s = fn(*args)
        return jnp.sum(o * jnp.cos(jnp.arange(o.size).reshape(o.shape))) \
            + jnp.sum(s)

    g_ref = jax.grad(lambda rr, kk: loss(
        _rwkv6_recurrence, (rr, kk, v, w, u, s0)), argnums=(0, 1)
    )(r, k)
    g_chk = jax.grad(lambda rr, kk: loss(
        lambda *a: _rwkv6_chunked(*a, chunk=8), (rr, kk, v, w, u, s0)),
        argnums=(0, 1)
    )(r, k)
    for a, b in zip(g_chk, g_ref):
        np.testing.assert_allclose(a, b, rtol=5e-5, atol=5e-5)


def test_chunked_state_carry_composes(rng_key):
    """Running two chunked halves back-to-back == one full pass."""
    r, k, v, w, u, s0 = _inputs(rng_key, S=64)
    o_full, s_full = _rwkv6_chunked(r, k, v, w, u, s0, chunk=16)
    half = 32
    o1, s1 = _rwkv6_chunked(r[:, :half], k[:, :half], v[:, :half],
                            w[:, :half], u, s0, chunk=16)
    o2, s2 = _rwkv6_chunked(r[:, half:], k[:, half:], v[:, half:],
                            w[:, half:], u, s1, chunk=16)
    np.testing.assert_allclose(
        np.concatenate([o1, o2], axis=1), o_full, rtol=2e-5, atol=2e-5
    )
    np.testing.assert_allclose(s2, s_full, rtol=2e-5, atol=2e-5)
