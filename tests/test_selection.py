"""The secure model-selection subsystem: λ-path CV as batched secure graphs.

Pins the tentpole contracts: (a) the batched scanned sweep converges to
the same per-(λ, fold) betas as sequential per-fold ``secure_fit`` calls
(the loop oracle) within fixed-point quantization, picks the same 1-SE λ,
and its revealed held-out aggregates equal plain evaluation; (b) the
multi-config secure round batches (C, S)-leading trees through one
protect/aggregate/reveal chain; (c) the SelectionCoordinator resumes
mid-path bit-identically, survives churn with fold assignments intact,
and fails loudly below the center threshold; (d) every secure driver
shares ONE stopping rule (the boundary-tolerance regression that
motivated the unification).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Institution,
    SecureAggregator,
    secure_fit,
)
from repro.core.logreg import deviance as deviance_fn
from repro.data import generate_synthetic
from repro.selection import (
    PathSettings,
    SelectionCoordinator,
    assign_folds,
    one_se_rule,
    secure_cv_path,
)

LAMBDAS = (3.0, 1.0, 0.3)
K = 3


@pytest.fixture(scope="module")
def study():
    return generate_synthetic(
        jax.random.PRNGKey(5), num_institutions=4,
        records_per_institution=300, dim=6,
    )


@pytest.fixture(scope="module")
def report(study):
    return secure_cv_path(
        study.parts, LAMBDAS, num_folds=K, protect="both", seed=0
    )


def _fold_arrays(parts):
    return [
        np.asarray(assign_folds(X.shape[0], K, j, 0))
        for j, (X, _) in enumerate(parts)
    ]


# ------------------------------------------------ sweep vs sequential oracle
def test_path_matches_sequential_loop_oracle(study, report):
    """Every (λ, fold) converged beta — and the refit — equals the
    sequential loop-path secure_fit on the physically-sliced train folds,
    within fixed-point quantization (the ladder's converged-beta
    contract for the f32-Gram rung)."""
    parts = study.parts
    agg = SecureAggregator(backend="pallas")
    quant = (len(parts) + 1) / agg.codec.scale
    folds = _fold_arrays(parts)
    assert report.fold_converged.all()
    for li, lam in enumerate(report.lambdas):
        for k in range(K):
            train = [(X[f != k], y[f != k])
                     for (X, y), f in zip(parts, folds)]
            ref = secure_fit(train, lam=float(lam), protect="both",
                             aggregator=agg, fused=False)
            err = np.abs(report.fold_betas[li, k] - ref.beta).max()
            assert err <= quant, (li, k, err)
    refit = secure_fit(parts, lam=report.lambda_1se, protect="both",
                       aggregator=agg, fused=False)
    assert np.abs(report.beta - refit.beta).max() <= quant


def test_revealed_heldout_aggregates_match_plain_eval(study, report):
    """The revealed per-(λ, fold) validation aggregates == plain
    evaluation of the fold betas on the held-out slices (sum over
    institutions), within fixed-point quantization per leaf."""
    parts = study.parts
    folds = _fold_arrays(parts)
    agg = SecureAggregator(backend="pallas")
    tol = (len(parts) + 1) / agg.codec.scale
    for li in range(len(report.lambdas)):
        for k in range(K):
            beta = jnp.asarray(report.fold_betas[li, k])
            dev = corr = cnt = 0.0
            for (X, y), f in zip(parts, folds):
                va = np.asarray(f) == k
                Xv, yv = X[va], y[va]
                dev += float(deviance_fn(beta, Xv, yv))
                z = np.asarray(Xv @ beta)
                corr += float(((z > 0) == (np.asarray(yv) > 0.5)).sum())
                cnt += float(va.sum())
            assert abs(report.val_deviance[li, k] - dev) <= tol
            assert report.val_correct[li, k] == corr
            assert report.val_count[li, k] == cnt


def test_warm_start_and_full_batch_agree(study):
    """lam_block=1 (max warm-start) and lam_block=L (the fully amortized
    single-batch shape) converge to the same fold betas — Newton's fixed
    point does not depend on the start — within quantization."""
    warm = secure_cv_path(study.parts, LAMBDAS, num_folds=K,
                          protect="gradient", lam_block=1, seed=0)
    flat = secure_cv_path(study.parts, LAMBDAS, num_folds=K,
                          protect="gradient", lam_block=len(LAMBDAS),
                          warm_start=False, seed=0)
    agg = SecureAggregator(backend="pallas")
    quant = (len(study.parts) + 1) / agg.codec.scale
    assert np.abs(warm.fold_betas - flat.fold_betas).max() <= 2 * quant
    assert warm.lambda_1se == flat.lambda_1se
    # warm starts must actually save rounds on the tail of the path
    assert warm.fold_rounds[1:].max() <= flat.fold_rounds[1:].max()


def test_protect_none_baseline(study, report):
    """protect='none' (the DataSHIELD-style insecure baseline) runs the
    same sweep shape without any secure round and agrees with the
    protected sweep (the module fixture) to quantization."""
    plain = secure_cv_path(study.parts, LAMBDAS, num_folds=K,
                           protect="none", seed=0)
    agg = SecureAggregator(backend="pallas")
    quant = (len(study.parts) + 1) / agg.codec.scale
    assert np.abs(plain.fold_betas - report.fold_betas).max() <= 2 * quant
    assert plain.bytes_per_round < report.bytes_per_round


@pytest.mark.parametrize("kw", [dict(protect="hessian"),
                                dict(protect="gradient", l1=0.3)])
def test_path_other_protect_modes_and_elastic_net(kw):
    """protect='hessian' and the elastic-net (l1 > 0, vmapped prox)
    sweep also hold converged parity with the sequential loop oracle."""
    small = generate_synthetic(jax.random.PRNGKey(2), num_institutions=3,
                               records_per_institution=250, dim=5)
    rep = secure_cv_path(small.parts, [2.0, 0.5], num_folds=2, seed=4,
                         **kw)
    agg = SecureAggregator(backend="pallas")
    quant = (len(small.parts) + 1) / agg.codec.scale
    folds = [np.asarray(assign_folds(X.shape[0], 2, j, 0))
             for j, (X, _) in enumerate(small.parts)]
    for li, lam in enumerate(rep.lambdas):
        for k in range(2):
            train = [(X[f != k], y[f != k])
                     for (X, y), f in zip(small.parts, folds)]
            ref = secure_fit(train, lam=float(lam), aggregator=agg,
                             fused=False, **kw)
            err = np.abs(rep.fold_betas[li, k] - ref.beta).max()
            assert err <= 5 * quant, (kw, li, k, err)


def test_round_budget_enforced_in_graph_and_metrics_consistent():
    """max_rounds binds per ROUND (not per scan block), and a config
    that exhausts its budget unconverged reports the beta its revealed
    held-out metrics were measured at (break-before-update on the last
    budgeted round) — the two code-review regressions."""
    from repro.core.logreg import deviance as dev_fn

    small = generate_synthetic(jax.random.PRNGKey(9), num_institutions=3,
                               records_per_institution=200, dim=5)
    rep = secure_cv_path(small.parts, [2.0, 0.5], num_folds=2,
                         max_rounds=4, rounds_per_sync=3, seed=1)
    assert rep.fold_rounds.max() <= 4  # not rounded up to a block edge

    rep2 = secure_cv_path(small.parts, [2.0, 0.5], num_folds=2,
                          max_rounds=2, rounds_per_sync=2, seed=1,
                          refit=False)
    assert not rep2.fold_converged.any()
    assert rep2.fold_rounds.max() == 2
    folds = [np.asarray(assign_folds(X.shape[0], 2, j, 0))
             for j, (X, _) in enumerate(small.parts)]
    for li in range(2):
        for k in range(2):
            beta = jnp.asarray(rep2.fold_betas[li, k])
            want = sum(
                float(dev_fn(beta, X[f == k], y[f == k]))
                for (X, y), f in zip(small.parts, folds)
            )
            assert abs(rep2.val_deviance[li, k] - want) < 1e-6


def test_one_se_rule_unit():
    lambdas = np.asarray([10.0, 1.0, 0.1])
    best, pick = one_se_rule(
        lambdas, np.asarray([5.0, 1.0, 0.99]), np.asarray([0.1, 0.1, 0.1])
    )
    assert best == 2       # minimum at the smallest λ
    assert pick == 1       # 1.0 is within 0.99 + 0.1 -> largest such λ
    best, pick = one_se_rule(
        lambdas, np.asarray([1.0, 2.0, 3.0]), np.asarray([0.0, 0.0, 0.0])
    )
    assert best == 0 and pick == 0


def test_settings_validation():
    with pytest.raises(ValueError, match="descending"):
        PathSettings(lambdas=(1.0, 3.0))
    with pytest.raises(ValueError, match="lam_block"):
        PathSettings(lambdas=(3.0, 1.0), lam_block=5)
    with pytest.raises(ValueError, match="protect"):
        PathSettings(lambdas=(1.0,), protect="everything")
    with pytest.raises(ValueError, match="max_rounds"):
        PathSettings(lambdas=(1.0,), max_rounds=0)
    with pytest.raises(ValueError, match="folds"):
        PathSettings(lambdas=(1.0,), num_folds=1)
    with pytest.raises(ValueError, match="descending"):
        PathSettings(lambdas=(1.0, 1.0))  # duplicates rejected too
    with pytest.raises(ValueError, match="pallas"):
        secure_cv_path([(jnp.ones((8, 2)), jnp.ones(8))], [1.0],
                       num_folds=2,
                       aggregator=SecureAggregator(backend="reference"))


def test_report_telemetry_static_shapes(report):
    """bytes/round comes from the static size model and matches the
    actual number of revealed leaves (protect=both: H + g + dev + count
    + 3 val scalars per config per institution)."""
    assert report.bytes_per_round > 0
    # λ-chunk rounds bill at bytes_per_round; the 1-config refit tail
    # bills at its own (smaller) static figure — the total sits between
    # the two bounds
    assert report.bytes_total <= \
        report.rounds_total * report.bytes_per_round
    assert report.bytes_total > \
        (report.rounds_total - report.refit_rounds) \
        * report.bytes_per_round // 2
    assert report.traces, "block readbacks must be recorded"
    # refit happened and is the final model
    assert report.beta is not None and report.refit_rounds > 0


@pytest.mark.slow
@pytest.mark.parametrize("summaries_backend", ["reference", "pallas",
                                               "mixed"])
def test_path_oracle_parity_production_shapes(summaries_backend):
    """`slow` rung sweep at a closer-to-benchmark shape: every summaries
    rung of the batched sweep holds converged-beta parity with the
    sequential loop oracle and picks the same λ.  Run with -m slow."""
    study = generate_synthetic(
        jax.random.PRNGKey(20), num_institutions=6,
        records_per_institution=4000, dim=24,
    )
    lambdas = (30.0, 3.0, 0.3)
    rep = secure_cv_path(study.parts, lambdas, num_folds=4,
                         protect="both", seed=3,
                         summaries_backend=summaries_backend)
    agg = SecureAggregator(backend="pallas")
    quant = (len(study.parts) + 1) / agg.codec.scale
    folds = [
        np.asarray(assign_folds(X.shape[0], 4, j, 0))
        for j, (X, _) in enumerate(study.parts)
    ]
    assert rep.fold_converged.all()
    for li, lam in enumerate(rep.lambdas):
        for k in range(4):
            train = [(X[f != k], y[f != k])
                     for (X, y), f in zip(study.parts, folds)]
            ref = secure_fit(train, lam=float(lam), protect="both",
                             aggregator=agg, fused=False)
            assert np.abs(rep.fold_betas[li, k] - ref.beta).max() <= quant


# ------------------------------------------------------ coordinator shape
def _make_coord(study, **kw):
    insts = [
        Institution(f"inst{j}", *study.parts[j])
        for j in range(len(study.parts))
    ]
    kw.setdefault("protect", "gradient")
    kw.setdefault("seed", 1)
    return SelectionCoordinator(insts, list(LAMBDAS), num_folds=K, **kw)


def test_coordinator_resume_mid_path_bitexact(study):
    full = _make_coord(study)
    rep_full = full.run_path()

    part1 = _make_coord(study)
    part1.step_chunk()
    part1.step_chunk()
    snap = {k: np.array(v) for k, v in part1.state_dict().items()}

    part2 = _make_coord(study)
    part2.load_state_dict(snap)
    assert part2.next_chunk == 2
    rep_res = part2.run_path()

    np.testing.assert_array_equal(rep_res.fold_betas, rep_full.fold_betas)
    np.testing.assert_array_equal(rep_res.beta, rep_full.beta)
    assert rep_res.lambda_1se == rep_full.lambda_1se
    assert rep_res.rounds_total == rep_full.rounds_total


def test_coordinator_churn_keeps_other_folds(study):
    """An institution leaving mid-path does not perturb the others'
    fold assignment, and the sweep completes on the shrunken cohort."""
    coord = _make_coord(study)
    coord.step_chunk()
    coord.remove_institution("inst3")
    rep = coord.run_path()
    assert rep.fold_converged.all()
    # churn-safety: fold ids of remaining institutions are name-pure
    f_before = np.asarray(assign_folds(300, K, "inst1", 0))
    f_after = np.asarray(assign_folds(300, K, "inst1", 0))
    np.testing.assert_array_equal(f_before, f_after)


def test_coordinator_center_dropout_raises(study):
    coord = _make_coord(study)
    for c in coord.study.centers[1:]:
        c.online = False
    with pytest.raises(RuntimeError, match="threshold"):
        coord.step_chunk()


def test_coordinator_surfaces_refit_on_study(study):
    coord = _make_coord(study)
    rep = coord.run_path()
    np.testing.assert_array_equal(np.asarray(coord.study.beta), rep.beta)
    assert coord.study.lam == rep.lambda_1se


# ------------------------------------------------- the one stopping rule
def test_stop_threshold_semantics():
    """Unit pin of the shared rule: relative tolerance vs quantization
    floor, and exact (strict <) behavior AT the boundary — the semantics
    every driver now inherits from the single implementation."""
    from repro.core.newton import should_stop, stop_threshold

    scale = 2.0**28
    # relative regime: threshold = tol * (1 + |obj|)
    thr = float(stop_threshold(100.0, 1e-6, 4, scale))
    assert thr == pytest.approx(1e-6 * 101.0)
    # quantization floor regime: S+1 half-ulps at the codec scale
    thr = float(stop_threshold(100.0, 1e-15, 4, scale))
    assert thr == (4 + 1) * 0.5 / scale
    # strict inequality at the boundary: |delta| == threshold does NOT
    # stop (matches every pre-unification driver's `<`)
    obj = 100.0
    t = float(stop_threshold(obj, 1e-6, 4, scale))
    assert not bool(should_stop(obj + t, obj, 1e-6, 4, scale))
    assert bool(should_stop(obj + t * (1 - 1e-6), obj, 1e-6, 4, scale))
    # vectorizes over a config axis (the selection scan's shape)
    objs = jnp.asarray([100.0, 200.0])
    prev = jnp.asarray([100.0 + 1e-9, 250.0])
    got = np.asarray(should_stop(prev, objs, 1e-6, 4, scale))
    np.testing.assert_array_equal(got, [True, False])


def test_all_drivers_share_one_stopping_rule(study, monkeypatch):
    """Structural pin of the satellite fix: secure_fit (loop AND fused)
    and StudyCoordinator (loop AND fused rounds) all route their
    convergence decision through newton's shared stopping rule —
    ``should_stop`` in traced graphs, its bit-pinned host twin
    ``should_stop_host`` on already-synced objectives (tests/
    test_analysis.py pins the pair IEEE-identical) — and form
    objectives through newton.regularized_objective; no driver
    re-derives its own threshold arithmetic, so they cannot drift
    apart at the tolerance boundary again."""
    import repro.core.newton as newton_mod
    import repro.core.protocol as protocol_mod
    from repro.core import StudyCoordinator

    parts = study.parts
    agg = SecureAggregator(backend="pallas")
    seen = []
    orig = newton_mod.should_stop
    orig_host = newton_mod.should_stop_host

    def spy(*a, **k):
        seen.append(True)
        return orig(*a, **k)

    def spy_host(*a, **k):
        seen.append(True)
        return orig_host(*a, **k)

    monkeypatch.setattr(newton_mod, "should_stop", spy)
    monkeypatch.setattr(newton_mod, "should_stop_host", spy_host)
    monkeypatch.setattr(protocol_mod, "should_stop_host", spy_host)

    def count(run):
        del seen[:]
        run()
        return len(seen)

    assert count(lambda: secure_fit(parts, aggregator=agg,
                                    fused=False, max_iter=3)) >= 3
    assert count(lambda: secure_fit(parts, aggregator=agg,
                                    fused=True, max_iter=3)) >= 3

    def run_coord(fused):
        insts = [Institution(f"i{j}", *p) for j, p in enumerate(parts)]
        c = StudyCoordinator(insts, aggregator=agg, fused=fused)
        c.run(max_iter=3)

    assert count(lambda: run_coord(False)) >= 3
    assert count(lambda: run_coord(True)) >= 3


def test_loop_and_fused_drivers_agree_on_iteration_count(study):
    """Agreement pin on the per-round-parity rung: the coordinator's
    loop and fused rounds (summaries_backend='reference') stop at the
    same iteration across a sweep of tolerances spanning the relative
    and quantization-floor regimes, with traces agreeing to the
    fixed-point grid.  (The fused secure_fit default rides the f32-Gram
    rung, whose mid-run transient legitimately perturbs objectives
    within quantization — iteration-count equality is only a contract
    where per-round parity is, i.e. on the reference rung.)"""
    from repro.core import StudyCoordinator

    parts = study.parts
    agg = SecureAggregator(backend="pallas")

    def run(fused, tol):
        insts = [Institution(f"i{j}", *p) for j, p in enumerate(parts)]
        c = StudyCoordinator(insts, lam=1.0, protect="both",
                             aggregator=agg, tol=tol, fused=fused,
                             summaries_backend="reference")
        c.run()
        return c.iteration, np.asarray(c.trace)

    for tol in (3e-4, 1e-6, 1e-8, 1e-11):
        it_l, tr_l = run(False, tol)
        it_f, tr_f = run(True, tol)
        assert it_l == it_f, f"iteration counts diverge at tol={tol}"
        np.testing.assert_allclose(
            tr_l, tr_f,
            atol=(len(parts) + 1) / agg.codec.scale,
            err_msg=f"traces diverge past quantization at tol={tol}",
        )
