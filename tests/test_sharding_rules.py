"""Property tests for the sharding rules (distributed/sharding.py).

Invariants the 256/512-chip dry-run relies on:
  * a sharded dim is always divisible by the product of its mesh axes,
  * specs never reuse a mesh axis twice within one PartitionSpec,
  * every (arch x parallelism-flag) combination yields valid specs for
    every parameter of the full config.
"""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_config
from repro.configs.perf_presets import apply_preset
from repro.distributed.sharding import MeshRules, param_pspec
from repro.models import transformer as T
from repro.models.config import SHAPES

LM_ARCHS = [a for a in ARCH_IDS if a != "logreg_paper"]


def _mesh_sizes():
    return {"data": 16, "model": 16}


def _axis_size(axis, sizes):
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= sizes[a]
        return n
    return sizes[axis]


class _FakeRules:
    """MeshRules stand-in with a fixed 16x16 shape, no device allocation."""

    tp_axis = "model"
    tp_size = 16
    dp_size = 16
    dp_axes = ("data",)
    mesh = object()  # truthy

    def fsdp_axes(self):
        return self.dp_axes


def _check_spec(spec, shape, sizes):
    used = []
    for dim, axis in enumerate(spec):
        if axis is None:
            continue
        n = _axis_size(axis, sizes)
        assert shape[dim] % n == 0, (spec, shape, dim)
        axes = axis if isinstance(axis, (tuple, list)) else (axis,)
        for a in axes:
            assert a not in used, f"axis {a} used twice in {spec}"
            used.append(a)


@pytest.mark.parametrize("arch", LM_ARCHS)
@pytest.mark.parametrize("flags", [
    {},
    {"fsdp_only": True},
    {"rwkv_batch_parallel": True},
    {"seq_parallel_prefill": True},
])
def test_param_specs_valid_for_all_archs(arch, flags):
    cfg = dataclasses.replace(get_config(arch), **flags)
    sizes = _mesh_sizes()
    rules = _FakeRules()
    params = T.abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        shape = leaf.shape
        if "segments" in pstr and leaf.ndim >= 1:
            spec = (None,) + tuple(
                param_pspec(pstr, shape[1:], rules, cfg)
            )
        else:
            spec = tuple(param_pspec(pstr, shape, rules, cfg))
        assert len(spec) <= len(shape) + 1
        _check_spec(spec[:len(shape)], shape, sizes)


@given(
    d=st.sampled_from([1024, 2560, 3840, 4096, 5120, 8192]),
    heads=st.sampled_from([8, 16, 24, 32, 40, 56, 64]),
    ff=st.sampled_from([1536, 10240, 11008, 27648, 29568]),
)
@settings(max_examples=40, deadline=None)
def test_attention_mlp_specs_never_overshard(d, heads, ff):
    cfg = dataclasses.replace(
        get_config("deepseek_7b"), d_model=d, num_heads=heads,
        num_kv_heads=heads, d_ff=ff,
    )
    sizes = _mesh_sizes()
    rules = _FakeRules()
    for name, shape in (("wq", (d, heads * 128)), ("wo", (heads * 128, d)),
                        ("w1", (d, ff)), ("w2", (ff, d))):
        spec = tuple(param_pspec(name, shape, rules, cfg))
        _check_spec(spec, shape, sizes)


def test_preset_application_is_pure():
    for arch in LM_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            out = apply_preset(cfg, shape)
            assert out.name == cfg.name
            # never mutates the original
            assert get_config(arch) == cfg
