# NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
# benchmarks must see the real single CPU device; only launch/dryrun.py (run
# as a subprocess) forces 512 placeholder devices.
import jax
import pytest

# repro.core enables x64 on import; import early so every test sees one state.
import repro.core  # noqa: F401


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
