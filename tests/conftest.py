# NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
# benchmarks must see the real single CPU device; only launch/dryrun.py (run
# as a subprocess) forces 512 placeholder devices.
import pathlib
import sys

# Property tests import hypothesis; the container does not ship it and tier-1
# must collect everywhere.  Register a deterministic fallback shim under the
# ``hypothesis`` name when the real package is missing (see
# _hypothesis_fallback.py; install requirements-dev.txt for the real thing).
try:  # pragma: no cover - trivially environment-dependent
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).parent))
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies

import jax
import pytest

# repro.core enables x64 on import; import early so every test sees one state.
import repro.core  # noqa: F401


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
