"""Paper reproduction: secure distributed Newton == centralized gold standard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FixedPointCodec,
    SecureAggregator,
    ShamirScheme,
    centralized_fit,
    deviance,
    local_summaries,
    secure_fit,
)
from repro.core.field import FIELD_WIDE
from repro.data import generate_synthetic, load_study


@pytest.fixture(scope="module")
def study():
    return generate_synthetic(
        jax.random.PRNGKey(3), num_institutions=5,
        records_per_institution=400, dim=8,
    )


@pytest.mark.parametrize("protect", ["none", "gradient", "hessian", "both"])
def test_secure_matches_gold(study, protect):
    """Fig. 2: R^2 = 1.00 against the pooled gold standard."""
    X, y = study.pooled()
    gold = centralized_fit(X, y, lam=1.0)
    sec = secure_fit(study.parts, lam=1.0, protect=protect)
    assert sec.converged and gold.converged
    np.testing.assert_allclose(sec.beta, gold.beta, atol=1e-6)
    r2 = np.corrcoef(sec.beta, gold.beta)[0, 1] ** 2
    assert r2 > 0.999999


def test_convergence_iterations_paper_range(study):
    """Fig. 3: convergence within 6-8 iterations at tol 1e-10."""
    sec = secure_fit(study.parts, lam=1.0, tol=1e-10, protect="gradient")
    assert sec.converged
    assert sec.iterations <= 10  # paper: 6-8 on its datasets
    # deviance trace must be non-increasing after the first step, up to the
    # fixed-point quantization of the protected dev_j values (~2**-20 each)
    t = sec.deviance_trace
    assert all(t[i + 1] <= t[i] + 1e-4 for i in range(1, len(t) - 1))


def test_regularization_shrinks_coefficients(study):
    X, y = study.pooled()
    small = centralized_fit(X, y, lam=0.01).beta
    big = centralized_fit(X, y, lam=100.0).beta
    assert np.linalg.norm(big) < np.linalg.norm(small)


def test_local_summaries_decompose_exactly(study):
    """Eqs. 4-6: sum of per-institution summaries == pooled summaries."""
    X, y = study.pooled()
    beta = jnp.asarray(np.random.default_rng(0).normal(size=X.shape[1]))
    pooled = local_summaries(beta, X, y)
    parts = [local_summaries(beta, Xj, yj) for Xj, yj in study.parts]
    np.testing.assert_allclose(
        pooled.hessian, sum(p.hessian for p in parts), rtol=1e-12
    )
    np.testing.assert_allclose(
        pooled.gradient, sum(p.gradient for p in parts), rtol=1e-12
    )
    np.testing.assert_allclose(
        pooled.deviance, sum(p.deviance for p in parts), rtol=1e-12
    )


def test_deviance_matches_direct(study):
    X, y = study.pooled()
    beta = jnp.zeros(X.shape[1], dtype=jnp.float64)
    # at beta=0: dev = -2 N log 0.5
    np.testing.assert_allclose(
        deviance(beta, X, y), 2 * X.shape[0] * np.log(2), rtol=1e-12
    )


def test_wider_codec_tightens_match(study):
    """Fixed-point scale controls the only approximation in the pipeline."""
    X, y = study.pooled()
    gold = centralized_fit(X, y, lam=1.0).beta
    errs = []
    for bits in (10, 20):
        agg = SecureAggregator(
            scheme=ShamirScheme(field=FIELD_WIDE),
            codec=FixedPointCodec(field=FIELD_WIDE, frac_bits=bits),
        )
        sec = secure_fit(study.parts, lam=1.0, protect="both", aggregator=agg)
        errs.append(np.abs(sec.beta - gold).max())
    assert errs[1] < errs[0]


def test_paper_datasets_all_converge_scaled():
    """All four evaluation studies (CI-scaled rows) converge quickly and
    match gold — structural reproduction of Table 1 / Fig 2-3."""
    for name in ("insurance", "parkinsons.motor", "parkinsons.total",
                 "synthetic"):
        st = load_study(name, scale=0.06)
        gold = centralized_fit(*st.pooled(), lam=st.lam)
        sec = secure_fit(st.parts, lam=st.lam, protect="gradient")
        assert sec.converged, name
        assert sec.iterations <= 12, (name, sec.iterations)
        np.testing.assert_allclose(sec.beta, gold.beta, atol=1e-5)
