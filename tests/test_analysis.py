"""Tier-1 coverage for the static privacy gate (src/repro/analysis).

Pins, in order: every certified driver spec verifying clean with the
expected declassification trail; every leak fixture being CAUGHT with a
finding naming the offending equation path; the host-sync lint passing
on the real driver sources and failing on the legacy multi-readback
pattern; the host stopping-rule twins bit-matching the traced versions;
the headroom lint's pass/fail boundary; the mesh-axis allowlist; the
Pallas knob lint; and the callback census of the scan graphs.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.__main__ import _analyze_spec
from repro.analysis.drivers import all_driver_specs
from repro.analysis.fixtures import leak_fixture_specs
from repro.analysis.lints import (SummaryBounds, lint_headroom,
                                  lint_host_sync, lint_kernel_knobs,
                                  lint_mesh_axes, lint_no_callbacks)
from repro.analysis.report import AnalysisReport, Finding

_SPECS = {s.name: s for s in all_driver_specs()}


# -- the certified surface -------------------------------------------------


@pytest.mark.parametrize("name", sorted(_SPECS))
def test_driver_certifies_clean(name):
    rep = _analyze_spec(_SPECS[name])
    assert rep.ok, rep.format(verbose=True)
    # every driver graph reveals something: the audit trail is non-empty
    assert rep.declassifications, f"{name}: no declassification recorded"


def test_gradient_mode_records_plaintext_declassification():
    """protect='gradient' routes H/deviance through the annotated
    declassify_sum — the audit trail must name it."""
    rep = _analyze_spec(_SPECS["secure_fit_fused[protect=gradient]"])
    assert any("declassify_sum" in d for d in rep.declassifications)
    assert any("_reveal_flat" in d for d in rep.declassifications)


def test_2d_mesh_uses_distributed_reveal():
    rep = _analyze_spec(_SPECS["secure_psum_2d"])
    assert any("_distributed_reveal" in d for d in rep.declassifications)


# -- negative controls -----------------------------------------------------


def _fixture(name):
    (spec,) = [s for s in leak_fixture_specs() if s.name == name]
    return _analyze_spec(spec, expect_leak=True)


def test_skip_protect_fixture_caught():
    rep = _fixture("LEAKY:skip_protect")
    assert not rep.ok
    assert any("outvars" in f.where and "SECRET" in f.message
               for f in rep.errors())


def test_reveal_slice_fixture_caught_at_the_reveal_eqn():
    """The acceptance case: a per-institution reveal is flagged with a
    finding naming the offending jaxpr equation path."""
    rep = _fixture("LEAKY:reveal_institution_slice")
    assert not rep.ok
    (f,) = [f for f in rep.errors() if "_reveal_flat" in f.where]
    assert "PER-INSTITUTION" in f.message
    assert "/eqn[" in f.where


def test_callback_fixture_caught_at_the_callback_eqn():
    rep = _fixture("LEAKY:callback_leak")
    assert not rep.ok
    assert any("debug_callback" in f.where for f in rep.errors())


# -- host-sync lint --------------------------------------------------------


def test_host_sync_lint_clean_on_repo_drivers():
    rep = lint_host_sync()
    assert rep.ok, rep.format(verbose=True)
    # one info finding per monitored method: the single marked sync
    infos = [f for f in rep.findings if f.severity == "info"]
    assert len(infos) == 5


_LEGACY_DRIVER = '''
import jax
import numpy as np

class Driver:
    def step_block(self):
        carry, objs, actives = fit_scan_block(self.beta)
        # host-sync: the block readback
        objs = jax.device_get(objs)
        # the legacy pattern: extra unmarked materializations, one per
        # carry element, each a separate device round-trip
        self._obj_prev = float(carry[1])
        self.converged = bool(carry[2])
        actives = np.asarray(actives)
        return objs
'''


def test_host_sync_lint_catches_legacy_multi_readback():
    rep = lint_host_sync(modules={
        "legacy.py": (_LEGACY_DRIVER, [("Driver", "step_block")]),
    })
    assert not rep.ok
    errs = rep.errors()
    # float(carry), bool(carry), np.asarray(actives): three stray syncs
    assert len(errs) == 3
    assert all("unannotated host materialization" in f.message
               for f in errs)
    assert any("float(carry)" in f.where for f in errs)


def test_host_sync_lint_requires_exactly_one_marked_site():
    doubled = _LEGACY_DRIVER.replace(
        "self._obj_prev = float(carry[1])",
        "# host-sync: a second one\n        "
        "self._obj_prev = float(carry[1])",
    ).replace("self.converged = bool(carry[2])", "pass") \
     .replace("actives = np.asarray(actives)", "pass")
    rep = lint_host_sync(modules={
        "doubled.py": (doubled, [("Driver", "step_block")]),
    })
    assert any("2 marked host-sync sites" in f.message
               for f in rep.errors())


# -- collective boundary-ownership lint ------------------------------------


def test_collective_sites_lint_clean_on_repo_tree():
    """The real package: every boundary call lives in an exempt file."""
    from repro.analysis.lints import lint_collective_sites

    rep = lint_collective_sites()
    assert rep.ok, rep.format(verbose=True)


def test_collective_sites_lint_flags_private_chain():
    """A driver growing its own protect -> reveal chain is an error;
    the same calls inside core/collective.py are the sanctioned owner."""
    from repro.analysis.lints import lint_collective_sites

    rogue = (
        "from repro.core.collective import _protect_flat, _reveal_flat\n"
        "def my_round(key, buf, scheme, frac_bits, rows, pts):\n"
        "    shares = _protect_flat(key, buf, scheme, frac_bits, rows)\n"
        "    return _reveal_flat(shares, scheme, frac_bits, pts)\n"
    )
    rep = lint_collective_sites(modules={"core/rogue.py": rogue})
    errs = rep.errors()
    assert len(errs) == 2
    assert all("outside core/collective.py" in f.message for f in errs)
    # identical source housed at the owner path is clean
    rep2 = lint_collective_sites(modules={"core/collective.py": rogue})
    assert rep2.ok


def test_collective_sites_lint_allows_imports_and_attributes():
    """Re-exports and attribute access don't build a chain — only calls
    (including method-style ``mod._reveal_flat(...)``) are flagged."""
    from repro.analysis.lints import lint_collective_sites

    compat = (
        "from .collective import _reveal_flat, _protect_flat\n"
        "SITES = ('_reveal_flat', '_distributed_reveal')\n"
        "handle = _reveal_flat\n"
    )
    rep = lint_collective_sites(modules={"core/compat.py": compat})
    assert rep.ok
    attr_call = (
        "from repro.core import collective\n"
        "out = collective._reveal_flat(b, s, f, p)\n"
    )
    rep2 = lint_collective_sites(modules={"selection/peek.py": attr_call})
    assert not rep2.ok


# -- stopping-rule host twins ----------------------------------------------


def test_should_stop_host_bitwise_matches_traced():
    from repro.core.newton import should_stop, should_stop_host

    grid = [0.0, 1e-12, 1e-6, 0.5, 1.0, 123.456, 1e12, np.inf]
    for prev in grid:
        for obj in [0.0, 1e-12, 0.4999, 123.456, 1e12, np.inf]:
            for tol, s, scale in [(1e-8, 3, 2.0 ** 28), (1e-4, 16, 8.0)]:
                dev = bool(should_stop(
                    jnp.float64(prev), jnp.float64(obj), tol, s, scale
                ))
                host = should_stop_host(prev, obj, tol, s, scale)
                assert dev == host, (prev, obj, tol, s, scale)


# -- headroom lint ---------------------------------------------------------


def test_headroom_lint_passes_deployment_envelope():
    rep = lint_headroom(SummaryBounds(d=128, n_max=100_000, num_parts=16))
    assert rep.ok, rep.format(verbose=True)
    infos = {f.where for f in rep.findings if f.severity == "info"}
    assert infos == {"aggregation", "codec"}


def test_headroom_lint_fails_past_codec_capacity():
    rep = lint_headroom(
        SummaryBounds(d=128, n_max=10 ** 9, num_parts=64)
    )
    assert not rep.ok
    assert any(f.where == "codec" for f in rep.errors())


def test_headroom_lint_fails_past_uint64_accumulator():
    rep = lint_headroom(
        SummaryBounds(d=4, n_max=10, num_parts=2 ** 35)
    )
    assert any(f.where == "aggregation" for f in rep.errors())


# -- mesh-axis lint --------------------------------------------------------


def test_mesh_axis_lint_flags_rogue_axis():
    from jax.sharding import AbstractMesh, PartitionSpec as P

    from repro.distributed.compat import shard_map

    mesh = AbstractMesh((("rogue", 4),))
    fn = shard_map(lambda x: jax.lax.psum(x, "rogue"), mesh=mesh,
                   in_specs=(P(),), out_specs=P(), check_vma=False)
    closed = jax.make_jaxpr(fn)(jnp.ones((8,)))
    rep = lint_mesh_axes(closed, "rogue-test")
    assert not rep.ok
    assert any("unknown axis 'rogue'" in f.message for f in rep.errors())


def test_mesh_axis_lint_passes_protocol_axes():
    spec = _SPECS["secure_psum[sharded,tile]"]
    closed, _ = spec.build()
    rep = lint_mesh_axes(closed, spec.name)
    assert rep.ok, rep.format(verbose=True)


# -- Pallas knob lint ------------------------------------------------------


def test_kernel_knob_lint_default_knobs_fit_vmem():
    rep = lint_kernel_knobs()
    assert rep.ok
    assert len([f for f in rep.findings if f.severity == "info"]) == 4


def test_kernel_knob_lint_rejects_misaligned_block():
    from repro.kernels.tuning import DEFAULT_KNOBS

    knobs = dict(DEFAULT_KNOBS)
    knobs["fused_irls"] = knobs["fused_irls"].replace(block_n=7)
    rep = lint_kernel_knobs(knobs=knobs)
    assert not rep.ok
    assert any("block_n=7" in f.message for f in rep.errors())


def test_kernel_knob_lint_rejects_oversized_working_set():
    from repro.kernels.tuning import DEFAULT_KNOBS

    knobs = dict(DEFAULT_KNOBS)
    knobs["shamir_protect_flat"] = \
        knobs["shamir_protect_flat"].replace(block_rows=1 << 20)
    rep = lint_kernel_knobs(knobs=knobs)
    assert not rep.ok


# -- callback census -------------------------------------------------------


def test_scan_driver_graphs_are_callback_free():
    spec = _SPECS["secure_fit_scan[protect=both]"]
    closed, _ = spec.build()
    rep = lint_no_callbacks(closed, spec.name)
    assert rep.ok
    assert any("callback-free" in f.message for f in rep.findings)


def test_callback_census_flags_injected_callback():
    def fn(x):
        jax.debug.callback(lambda v: None, x)
        return x * 2.0

    closed = jax.make_jaxpr(fn)(jnp.ones((4,)))
    rep = lint_no_callbacks(closed, "injected")
    assert not rep.ok


# -- report plumbing -------------------------------------------------------


def test_report_dedup_and_severity_gate():
    rep = AnalysisReport(target="t")
    f = Finding("taint", "warning", "w", "m")
    rep.add(f)
    rep.add(f)
    assert len(rep.findings) == 1 and rep.ok
    rep.add(Finding("taint", "error", "w2", "m2"))
    assert not rep.ok and len(rep.errors()) == 1
    with pytest.raises(ValueError):
        Finding("taint", "fatal", "w", "m")
