"""In-SPMD secure_psum: flat-buffer wire vs per-leaf oracle, reveal modes,
t-subset reconstruction, overflow/headroom guards.

The single-device matrix runs in-process; the uneven-device-count case
(mesh sizes that do not divide the 8-row sublane alignment) runs as a
subprocess because XLA_FLAGS must be owned before jax initializes (same
idiom as test_dryrun_smoke).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.secure_agg import (
    SecureAggregator,
    check_aggregation_headroom,
    secure_psum,
)
from repro.core.shamir import ShamirScheme
from repro.distributed.compat import shard_map

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(key):
    return {
        "g": 0.5 * jax.random.normal(key, (300,), jnp.float32),
        "h": jnp.float32(3.25) * jnp.ones((4, 4), jnp.float32),
    }


def _run_psum(tree, agg, reveal, points=None):
    mesh = jax.make_mesh((1,), ("pod",))
    return shard_map(
        lambda: secure_psum(tree, "pod", jax.random.PRNGKey(5),
                            aggregator=agg, reveal=reveal, points=points),
        mesh=mesh, in_specs=(), out_specs=P(), check_vma=False,
    )()


@pytest.mark.parametrize("backend,reveal", [
    ("reference", "replicated"),
    ("pallas", "replicated"),
    ("pallas", "sharded"),
])
def test_secure_psum_exact_inside_spmd(backend, reveal, rng_key):
    """Every backend x reveal mode reveals exactly the global sum."""
    tree = _tree(rng_key)
    out = _run_psum(tree, SecureAggregator(backend=backend), reveal)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(tree[k]),
                                   atol=1e-5)


def test_secure_psum_backends_agree_bitwise(rng_key):
    """Flat wire == per-leaf oracle, bit-for-bit: both reveal the exact
    field encoding of the sum, so the decoded floats are identical."""
    tree = _tree(rng_key)
    ref = _run_psum(tree, SecureAggregator(backend="reference"), "replicated")
    for reveal in ("replicated", "sharded"):
        pal = _run_psum(tree, SecureAggregator(backend="pallas"), reveal)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(ref[k]),
                                          np.asarray(pal[k]))


def test_secure_psum_any_t_subset_matches(rng_key):
    """Reveal from ANY t-subset of points == the default reconstruction
    (exact field arithmetic), on both backends."""
    tree = _tree(rng_key)
    subsets = [(1, 2), (2, 5), (3, 4), (1, 5)]
    for backend in ("reference", "pallas"):
        agg = SecureAggregator(
            scheme=ShamirScheme(threshold=2, num_shares=5, backend=backend)
        )
        base = _run_psum(tree, agg, "replicated")
        for pts in subsets:
            got = _run_psum(tree, agg, "replicated", points=pts)
            for k in tree:
                np.testing.assert_array_equal(np.asarray(base[k]),
                                              np.asarray(got[k]))


def test_secure_psum_below_threshold_raises(rng_key):
    """A short point list must raise, never reduce a short share axis."""
    tree = _tree(rng_key)
    for backend in ("reference", "pallas"):
        agg = SecureAggregator(
            scheme=ShamirScheme(threshold=3, num_shares=5, backend=backend)
        )
        with pytest.raises(ValueError, match="irrecoverable"):
            _run_psum(tree, agg, "replicated", points=(1, 2))


def test_secure_psum_sharded_requires_flat_wire(rng_key):
    with pytest.raises(ValueError, match="sharded"):
        _run_psum(_tree(rng_key), SecureAggregator(backend="reference"),
                  "sharded")
    with pytest.raises(ValueError, match="reveal"):
        _run_psum(_tree(rng_key), SecureAggregator(backend="pallas"),
                  "scattered")


def test_aggregation_headroom_guard():
    """The shared exact-sum bound: S * max(p_r) < 2**64."""
    field = SecureAggregator().scheme.field
    check_aggregation_headroom(2**33, field)  # 2**33 * (2**31 - 1) fits
    with pytest.raises(ValueError, match="2\\*\\*64"):
        check_aggregation_headroom(2**34, field)


_MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compat import shard_map
    from repro.core.secure_agg import SecureAggregator, secure_psum

    D = 3  # does not divide the 8-row sublane alignment: rows pad to lcm
    tree = {
        "g": 0.5 * jax.random.normal(jax.random.PRNGKey(1), (300,),
                                     jnp.float32),
        "h": jnp.float32(3.25) * jnp.ones((4, 4), jnp.float32),
    }
    mesh = jax.make_mesh((D,), ("pod",))
    outs = {}
    for backend, reveal in (("reference", "replicated"),
                            ("pallas", "replicated"),
                            ("pallas", "sharded")):
        agg = SecureAggregator(backend=backend)
        out = shard_map(
            lambda: secure_psum(tree, "pod", jax.random.PRNGKey(5),
                                aggregator=agg, reveal=reveal),
            mesh=mesh, in_specs=(), out_specs=P(), check_vma=False,
        )()
        outs[(backend, reveal)] = out
        for k in tree:
            np.testing.assert_allclose(
                np.asarray(out[k]), D * np.asarray(tree[k]), atol=1e-5)
    ref = outs[("reference", "replicated")]
    for combo, out in outs.items():
        for k in tree:
            np.testing.assert_array_equal(np.asarray(ref[k]),
                                          np.asarray(out[k]))
    print("MULTIDEV_OK")
""")


def test_secure_psum_uneven_device_count(tmp_path):
    """3 devices (rows pad to lcm(8, 3)): all wire formats and reveal
    modes agree bitwise and match D * tree.  Subprocess: the forced host
    device count must be set before jax initializes."""
    script = tmp_path / "psum_multidev.py"
    script.write_text(_MULTIDEV_SCRIPT)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MULTIDEV_OK" in r.stdout
