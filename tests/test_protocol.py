"""Fault-tolerance behaviour of the Algorithm-1 coordinator.

The whole module runs under BOTH secure-aggregation backends (the uint64
reference oracle and the fused Pallas flat-buffer pipeline) — the protocol
semantics must be identical through either.
"""
import jax
import numpy as np
import pytest

from repro.core import (
    Institution,
    SecureAggregator,
    ShamirScheme,
    StudyCoordinator,
    centralized_fit,
)
from repro.data import generate_synthetic


@pytest.fixture(params=["reference", "pallas"])
def backend(request):
    return request.param


def make_insts(num=4, n=300, dim=6, latencies=None):
    study = generate_synthetic(
        jax.random.PRNGKey(11), num_institutions=num,
        records_per_institution=n, dim=dim,
    )
    lat = latencies or [0.0] * num
    return study, [
        Institution(f"inst{j}", *study.parts[j], latency=lat[j])
        for j in range(num)
    ]


def test_full_cohort_matches_gold(backend):
    study, insts = make_insts()
    coord = StudyCoordinator(
        insts, lam=1.0, protect="both",
        aggregator=SecureAggregator(backend=backend),
    )
    beta = coord.run()
    gold = centralized_fit(*study.pooled(), lam=1.0)
    np.testing.assert_allclose(beta, gold.beta, atol=1e-6)


def test_center_failures_within_threshold_are_free(backend):
    study, insts = make_insts()
    agg = SecureAggregator(
        scheme=ShamirScheme(threshold=2, num_shares=5, backend=backend)
    )
    coord = StudyCoordinator(insts, protect="both", aggregator=agg)
    coord.centers[0].online = False
    coord.centers[3].online = False
    coord.centers[4].online = False  # 2 alive == threshold
    beta = coord.run()
    gold = centralized_fit(*study.pooled(), lam=1.0)
    np.testing.assert_allclose(beta, gold.beta, atol=1e-6)


def test_too_many_center_failures_detected(backend):
    _, insts = make_insts()
    coord = StudyCoordinator(
        insts, protect="both", aggregator=SecureAggregator(backend=backend)
    )
    coord.centers[0].online = False
    coord.centers[1].online = False  # 1 alive < t=2
    with pytest.raises(RuntimeError, match="unrecoverable"):
        coord.step()


def test_straggler_excluded_then_rejoins(backend):
    study, insts = make_insts(latencies=[0.0, 0.0, 0.0, 9.9])
    coord = StudyCoordinator(
        insts, protect="gradient", deadline=1.0, min_responders=2,
        aggregator=SecureAggregator(backend=backend),
    )
    r1 = coord.step()
    assert r1.stragglers == ["inst3"]
    insts[3].latency = 0.0  # straggler recovers
    r2 = coord.step()
    assert "inst3" in r2.responders


def test_min_responders_enforced():
    _, insts = make_insts(latencies=[5.0, 5.0, 5.0, 0.0])
    coord = StudyCoordinator(insts, deadline=1.0, min_responders=3)
    with pytest.raises(RuntimeError, match="responders"):
        coord.step()


def test_elastic_membership(backend):
    study, insts = make_insts(num=4)
    coord = StudyCoordinator(
        insts[:3], protect="gradient",
        aggregator=SecureAggregator(backend=backend),
    )
    coord.step()
    coord.add_institution(insts[3])
    r = coord.step()
    assert "inst3" in r.responders
    coord.remove_institution("inst0")
    r = coord.step()
    assert "inst0" not in r.responders


def test_checkpoint_resume_bitexact(backend):
    study, insts = make_insts()
    a = StudyCoordinator(
        insts, protect="both", seed=5,
        aggregator=SecureAggregator(backend=backend),
    )
    for _ in range(2):
        a.step()
    state = a.state_dict()
    # clone coordinator, restore, then both must evolve identically
    b = StudyCoordinator(
        [Institution(i.name, i.X, i.y) for i in insts], protect="both",
        seed=5, aggregator=SecureAggregator(backend=backend),
    )
    b.load_state_dict(state)
    ra, rb = a.step(), b.step()
    np.testing.assert_array_equal(np.asarray(a.beta), np.asarray(b.beta))
    assert ra.objective == rb.objective


# ---------------------------------------------- fused cohort-level rounds
def make_fused_pair(protect="both", num=4, seed=5, uneven=False):
    """Loop-oracle and fused coordinators over the SAME institutions."""
    if uneven:
        base = generate_synthetic(
            jax.random.PRNGKey(11), num_institutions=1,
            records_per_institution=1200, dim=6,
        )
        X, y = base.pooled()
        sizes, parts, off = [7, 293, 500, 400], [], 0
        for s in sizes:
            parts.append((X[off:off + s], y[off:off + s]))
            off += s
        insts = [Institution(f"inst{j}", *parts[j]) for j in range(num)]
    else:
        _, insts = make_insts(num=num)
    agg = SecureAggregator(backend="pallas")

    def clone(fused):
        copies = [Institution(i.name, i.X, i.y) for i in insts]
        return StudyCoordinator(
            copies, lam=1.0, protect=protect, aggregator=agg, seed=seed,
            fused=fused,
        )

    return clone(False), clone(True)


@pytest.mark.parametrize("protect", ["none", "gradient", "hessian", "both"])
def test_fused_round_matches_loop_oracle(protect):
    """Per-round beta/objective parity within fixed-point quantization,
    every protect mode, deliberately ragged partitions (one institution
    smaller than a kernel block)."""
    loop, fus = make_fused_pair(protect=protect, uneven=True)
    quant = (len(loop.institutions) + 1) / loop.agg.codec.scale
    for _ in range(6):
        if loop.converged or fus.converged:
            break
        ra, rb = loop.step(), fus.step()
        assert abs(ra.objective - rb.objective) <= max(1e-9, quant * 10)
        err = np.abs(np.asarray(loop.beta) - np.asarray(fus.beta)).max()
        assert err <= quant
        # telemetry comes from static shapes and must agree across paths
        assert ra.bytes_transmitted == rb.bytes_transmitted
        assert ra.responders == rb.responders
    assert loop.converged == fus.converged


def test_fused_step_churn_between_rounds():
    """add/remove institution between rounds: the fused path repacks the
    new cohort (never reuses a stale padded batch) and stays within
    quantization of the loop oracle through the churn."""
    study, insts = make_insts(num=4)
    agg = SecureAggregator(backend="pallas")

    def clone(fused):
        return StudyCoordinator(
            [Institution(i.name, i.X, i.y) for i in insts[:3]],
            protect="gradient", aggregator=agg, seed=9, fused=fused,
        )

    loop, fus = clone(False), clone(True)
    quant = 5 / agg.codec.scale
    la, fa = loop.step(), fus.step()
    assert la.responders == fa.responders == ["inst0", "inst1", "inst2"]
    # join: both coordinators see the same 4-strong cohort
    loop.add_institution(Institution(insts[3].name, insts[3].X, insts[3].y))
    fus.add_institution(Institution(insts[3].name, insts[3].X, insts[3].y))
    lb, fb = loop.step(), fus.step()
    assert "inst3" in fb.responders
    assert np.abs(np.asarray(loop.beta) - np.asarray(fus.beta)).max() <= quant
    # leave: cohort shrinks, fused pack must follow
    loop.remove_institution("inst0")
    fus.remove_institution("inst0")
    lc, fc = loop.step(), fus.step()
    assert "inst0" not in fc.responders
    assert lc.responders == fc.responders
    assert lc.bytes_transmitted == fc.bytes_transmitted
    assert np.abs(np.asarray(loop.beta) - np.asarray(fus.beta)).max() <= quant


def test_fused_straggler_fallback_cohort():
    """A straggler shrinks the co-scheduled cohort; the fused round runs
    on the responding subset exactly like the loop round."""
    _, insts = make_insts(latencies=[0.0, 0.0, 0.0, 9.9])
    fus = StudyCoordinator(
        [Institution(i.name, i.X, i.y, latency=i.latency) for i in insts],
        protect="gradient", deadline=1.0, min_responders=2,
        aggregator=SecureAggregator(backend="pallas"), fused=True,
    )
    r1 = fus.step()
    assert r1.stragglers == ["inst3"]
    assert r1.responders == ["inst0", "inst1", "inst2"]
    fus.institutions[3].latency = 0.0
    r2 = fus.step()
    assert "inst3" in r2.responders


def test_fused_center_dropout_semantics():
    """Center failures within t-of-w are free in the fused round (reveal
    uses the live centers' actual points); below threshold the fused
    round raises the SAME RuntimeError as the loop — it must never
    reduce over a short share axis."""
    study, insts = make_insts()
    agg = SecureAggregator(
        scheme=ShamirScheme(threshold=2, num_shares=5, backend="pallas")
    )
    coord = StudyCoordinator(insts, protect="both", aggregator=agg,
                             fused=True)
    coord.centers[0].online = False
    coord.centers[3].online = False
    coord.centers[4].online = False  # 2 alive == threshold
    beta = coord.run()
    gold = centralized_fit(*study.pooled(), lam=1.0)
    np.testing.assert_allclose(beta, gold.beta, atol=1e-6)
    # drop one more mid-run: below threshold
    coord2 = StudyCoordinator(
        [Institution(i.name, i.X, i.y) for i in insts], protect="both",
        aggregator=agg, fused=True,
    )
    coord2.step()
    for c in coord2.centers[:4]:
        c.online = False  # 1 alive < t=2
    with pytest.raises(RuntimeError, match="unrecoverable"):
        coord2.step()


def test_fused_state_dict_roundtrip_bitexact():
    """Checkpoint/restore of the fused coordinator: the restored clone
    evolves bit-identically (same rng stream, same packed cohort)."""
    _, insts = make_insts()
    agg = SecureAggregator(backend="pallas")
    a = StudyCoordinator(insts, protect="both", seed=5, aggregator=agg,
                         fused=True)
    for _ in range(2):
        a.step()
    state = a.state_dict()
    b = StudyCoordinator(
        [Institution(i.name, i.X, i.y) for i in insts], protect="both",
        seed=5, aggregator=agg, fused=True,
    )
    b.load_state_dict(state)
    ra, rb = a.step(), b.step()
    np.testing.assert_array_equal(np.asarray(a.beta), np.asarray(b.beta))
    assert ra.objective == rb.objective


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("protect", ["none", "gradient", "hessian", "both"])
def test_round_bytes_matches_actual_messages(backend, protect):
    """The static telemetry formula equals a per-leaf walk over the real
    messages a round produces (the measurement the formula replaced) —
    including the per-center slicing when a center is offline."""
    _, insts = make_insts(num=3, n=40)
    agg = SecureAggregator(backend=backend)
    coord = StudyCoordinator(insts[:3], protect=protect, aggregator=agg)
    coord.centers[0].online = False  # 2 of 3 online, still >= t
    rep = coord.step()
    num_live = sum(1 for c in coord.centers if c.online)
    w = agg.scheme.num_shares
    nbytes = 0
    for inst in coord.institutions:
        shares, plain = inst.compute_and_protect(
            coord.beta, protect, agg, jax.random.PRNGKey(0)
        )
        if shares:
            share_bytes = sum(
                leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree_util.tree_leaves(shares)
            )
            nbytes += (share_bytes // w) * num_live
        nbytes += sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree_util.tree_leaves(plain)
        )
    assert rep.bytes_transmitted == nbytes


@pytest.mark.parametrize("summaries_backend", ["pallas", "mixed"])
def test_fused_f32_rung_converged_parity(summaries_backend):
    """The f32-Gram summaries rungs (the TPU layouts) hold the relaxed
    ``secure_fit`` contract: same round count and CONVERGED beta within
    quantization of the loop oracle (per-round parity is the f64 default
    rung's contract — the Newton transient amplifies f32 H error)."""
    _, insts = make_insts()
    agg = SecureAggregator(backend="pallas")
    loop = StudyCoordinator(insts, protect="both", aggregator=agg, seed=3)
    fus = StudyCoordinator(
        [Institution(i.name, i.X, i.y) for i in insts], protect="both",
        aggregator=agg, seed=3, fused=True,
        summaries_backend=summaries_backend,
    )
    beta_l, beta_f = loop.run(), fus.run()
    quant = (len(insts) + 1) / agg.codec.scale
    assert fus.converged and loop.converged
    assert fus.iteration == loop.iteration
    assert np.abs(beta_l - beta_f).max() <= quant


def test_fused_requires_pallas_backend():
    _, insts = make_insts()
    with pytest.raises(ValueError, match="pallas"):
        StudyCoordinator(insts, aggregator=SecureAggregator(), fused=True)
    coord = StudyCoordinator(insts, aggregator=SecureAggregator())
    with pytest.raises(ValueError, match="pallas"):
        coord.step(fused=True)
    with pytest.raises(ValueError, match="summaries_backend"):
        StudyCoordinator(insts, aggregator=SecureAggregator(backend="pallas"),
                         fused=True, summaries_backend="nope")


def test_fused_and_loop_rounds_interleave():
    """step(fused=...) overrides per round; the two shapes share all
    round state so they can alternate inside one fit."""
    study, insts = make_insts()
    coord = StudyCoordinator(
        insts, protect="both", aggregator=SecureAggregator(backend="pallas"),
    )
    for k in range(6):
        if coord.converged:
            break
        coord.step(fused=(k % 2 == 1))
    gold = centralized_fit(*study.pooled(), lam=1.0)
    np.testing.assert_allclose(
        np.asarray(coord.run()), gold.beta, atol=1e-6
    )


def test_backends_agree_bitexact():
    """Reference and Pallas coordinators converge to identical traces: the
    revealed aggregates are exact field sums either way, and the fused
    float64 encode is bit-compatible with the codec."""
    _, insts_a = make_insts()
    _, insts_b = make_insts()
    a = StudyCoordinator(
        insts_a, protect="both", seed=7,
        aggregator=SecureAggregator(backend="reference"),
    )
    b = StudyCoordinator(
        insts_b, protect="both", seed=7,
        aggregator=SecureAggregator(backend="pallas"),
    )
    beta_a, beta_b = a.run(), b.run()
    np.testing.assert_array_equal(np.asarray(beta_a), np.asarray(beta_b))
    assert a.trace == b.trace
