"""Fault-tolerance behaviour of the Algorithm-1 coordinator."""
import jax
import numpy as np
import pytest

from repro.core import (
    Institution,
    SecureAggregator,
    ShamirScheme,
    StudyCoordinator,
    centralized_fit,
)
from repro.data import generate_synthetic


def make_insts(num=4, n=300, dim=6, latencies=None):
    study = generate_synthetic(
        jax.random.PRNGKey(11), num_institutions=num,
        records_per_institution=n, dim=dim,
    )
    lat = latencies or [0.0] * num
    return study, [
        Institution(f"inst{j}", *study.parts[j], latency=lat[j])
        for j in range(num)
    ]


def test_full_cohort_matches_gold():
    study, insts = make_insts()
    coord = StudyCoordinator(insts, lam=1.0, protect="both")
    beta = coord.run()
    gold = centralized_fit(*study.pooled(), lam=1.0)
    np.testing.assert_allclose(beta, gold.beta, atol=1e-6)


def test_center_failures_within_threshold_are_free():
    study, insts = make_insts()
    agg = SecureAggregator(scheme=ShamirScheme(threshold=2, num_shares=5))
    coord = StudyCoordinator(insts, protect="both", aggregator=agg)
    coord.centers[0].online = False
    coord.centers[3].online = False
    coord.centers[4].online = False  # 2 alive == threshold
    beta = coord.run()
    gold = centralized_fit(*study.pooled(), lam=1.0)
    np.testing.assert_allclose(beta, gold.beta, atol=1e-6)


def test_too_many_center_failures_detected():
    _, insts = make_insts()
    coord = StudyCoordinator(insts, protect="both")
    coord.centers[0].online = False
    coord.centers[1].online = False  # 1 alive < t=2
    with pytest.raises(RuntimeError, match="unrecoverable"):
        coord.step()


def test_straggler_excluded_then_rejoins():
    study, insts = make_insts(latencies=[0.0, 0.0, 0.0, 9.9])
    coord = StudyCoordinator(
        insts, protect="gradient", deadline=1.0, min_responders=2
    )
    r1 = coord.step()
    assert r1.stragglers == ["inst3"]
    insts[3].latency = 0.0  # straggler recovers
    r2 = coord.step()
    assert "inst3" in r2.responders


def test_min_responders_enforced():
    _, insts = make_insts(latencies=[5.0, 5.0, 5.0, 0.0])
    coord = StudyCoordinator(insts, deadline=1.0, min_responders=3)
    with pytest.raises(RuntimeError, match="responders"):
        coord.step()


def test_elastic_membership():
    study, insts = make_insts(num=4)
    coord = StudyCoordinator(insts[:3], protect="gradient")
    coord.step()
    coord.add_institution(insts[3])
    r = coord.step()
    assert "inst3" in r.responders
    coord.remove_institution("inst0")
    r = coord.step()
    assert "inst0" not in r.responders


def test_checkpoint_resume_bitexact():
    study, insts = make_insts()
    a = StudyCoordinator(insts, protect="both", seed=5)
    for _ in range(2):
        a.step()
    state = a.state_dict()
    # clone coordinator, restore, then both must evolve identically
    b = StudyCoordinator(
        [Institution(i.name, i.X, i.y) for i in insts], protect="both", seed=5
    )
    b.load_state_dict(state)
    ra, rb = a.step(), b.step()
    np.testing.assert_array_equal(np.asarray(a.beta), np.asarray(b.beta))
    assert ra.objective == rb.objective
