"""Fault-tolerance behaviour of the Algorithm-1 coordinator.

The whole module runs under BOTH secure-aggregation backends (the uint64
reference oracle and the fused Pallas flat-buffer pipeline) — the protocol
semantics must be identical through either.
"""
import jax
import numpy as np
import pytest

from repro.core import (
    Institution,
    SecureAggregator,
    ShamirScheme,
    StudyCoordinator,
    centralized_fit,
)
from repro.data import generate_synthetic


@pytest.fixture(params=["reference", "pallas"])
def backend(request):
    return request.param


def make_insts(num=4, n=300, dim=6, latencies=None):
    study = generate_synthetic(
        jax.random.PRNGKey(11), num_institutions=num,
        records_per_institution=n, dim=dim,
    )
    lat = latencies or [0.0] * num
    return study, [
        Institution(f"inst{j}", *study.parts[j], latency=lat[j])
        for j in range(num)
    ]


def test_full_cohort_matches_gold(backend):
    study, insts = make_insts()
    coord = StudyCoordinator(
        insts, lam=1.0, protect="both",
        aggregator=SecureAggregator(backend=backend),
    )
    beta = coord.run()
    gold = centralized_fit(*study.pooled(), lam=1.0)
    np.testing.assert_allclose(beta, gold.beta, atol=1e-6)


def test_center_failures_within_threshold_are_free(backend):
    study, insts = make_insts()
    agg = SecureAggregator(
        scheme=ShamirScheme(threshold=2, num_shares=5, backend=backend)
    )
    coord = StudyCoordinator(insts, protect="both", aggregator=agg)
    coord.centers[0].online = False
    coord.centers[3].online = False
    coord.centers[4].online = False  # 2 alive == threshold
    beta = coord.run()
    gold = centralized_fit(*study.pooled(), lam=1.0)
    np.testing.assert_allclose(beta, gold.beta, atol=1e-6)


def test_too_many_center_failures_detected(backend):
    _, insts = make_insts()
    coord = StudyCoordinator(
        insts, protect="both", aggregator=SecureAggregator(backend=backend)
    )
    coord.centers[0].online = False
    coord.centers[1].online = False  # 1 alive < t=2
    with pytest.raises(RuntimeError, match="unrecoverable"):
        coord.step()


def test_straggler_excluded_then_rejoins(backend):
    study, insts = make_insts(latencies=[0.0, 0.0, 0.0, 9.9])
    coord = StudyCoordinator(
        insts, protect="gradient", deadline=1.0, min_responders=2,
        aggregator=SecureAggregator(backend=backend),
    )
    r1 = coord.step()
    assert r1.stragglers == ["inst3"]
    insts[3].latency = 0.0  # straggler recovers
    r2 = coord.step()
    assert "inst3" in r2.responders


def test_min_responders_enforced():
    _, insts = make_insts(latencies=[5.0, 5.0, 5.0, 0.0])
    coord = StudyCoordinator(insts, deadline=1.0, min_responders=3)
    with pytest.raises(RuntimeError, match="responders"):
        coord.step()


def test_elastic_membership(backend):
    study, insts = make_insts(num=4)
    coord = StudyCoordinator(
        insts[:3], protect="gradient",
        aggregator=SecureAggregator(backend=backend),
    )
    coord.step()
    coord.add_institution(insts[3])
    r = coord.step()
    assert "inst3" in r.responders
    coord.remove_institution("inst0")
    r = coord.step()
    assert "inst0" not in r.responders


def test_checkpoint_resume_bitexact(backend):
    study, insts = make_insts()
    a = StudyCoordinator(
        insts, protect="both", seed=5,
        aggregator=SecureAggregator(backend=backend),
    )
    for _ in range(2):
        a.step()
    state = a.state_dict()
    # clone coordinator, restore, then both must evolve identically
    b = StudyCoordinator(
        [Institution(i.name, i.X, i.y) for i in insts], protect="both",
        seed=5, aggregator=SecureAggregator(backend=backend),
    )
    b.load_state_dict(state)
    ra, rb = a.step(), b.step()
    np.testing.assert_array_equal(np.asarray(a.beta), np.asarray(b.beta))
    assert ra.objective == rb.objective


def test_backends_agree_bitexact():
    """Reference and Pallas coordinators converge to identical traces: the
    revealed aggregates are exact field sums either way, and the fused
    float64 encode is bit-compatible with the codec."""
    _, insts_a = make_insts()
    _, insts_b = make_insts()
    a = StudyCoordinator(
        insts_a, protect="both", seed=7,
        aggregator=SecureAggregator(backend="reference"),
    )
    b = StudyCoordinator(
        insts_b, protect="both", seed=7,
        aggregator=SecureAggregator(backend="pallas"),
    )
    beta_a, beta_b = a.run(), b.run()
    np.testing.assert_array_equal(np.asarray(beta_a), np.asarray(beta_b))
    assert a.trace == b.trace
