#!/usr/bin/env bash
# Standing perf/correctness gate for the secure-aggregation hot path.
#
# Runs tier-1 tests, the static privacy gate (scripts/static_checks.sh:
# jaxpr taint verification of every secure driver + protocol lints +
# leak-fixture negative controls), then a small-size secure_overhead
# smoke with BOTH
# backends and asserts (a) revealed-sum exactness on every row and (b) the
# fused Pallas pipeline is not slower than the reference oracle.  Then
# runs the e2e fused-Newton smoke (--quick) and asserts secure ==
# centralized beta (R^2 = 1) and fused == pre-fusion-loop beta within
# fixed-point quantization plus COLLECTIVE PARITY against the committed
# smoke baselines (per-round bytes exact, fused-path wall clock within
# 3% — the SecureCollective chain must not drift), the secure_psum
# smoke (sharded flat wire
# payload <= 0.55x the per-leaf uint64 tree, bit-equal reveals), the
# lambda-path smoke, the fault-overhead smoke (supervised rounds at
# negligible overhead + three chaos schedules recovering to the
# fault-free oracle), and the multihost-rounds smoke (scan residency =
# one host sync per fit at loop-oracle beta parity; CPU-mesh round
# latency flat in S; 2D distributed reveal bitwise vs the 1D wire;
# real-kernel knob validation).  Between the static gate and the perf
# smokes it runs the RUNTIME privacy audit (`python -m repro.obs
# audit`: executed declassification counts reconciled against every
# gate-certified graph, extra-reveal self-test flagged) and the
# obs-overhead smoke (span tracing <= gate%/round per driver shape,
# traced beta bit-identical to untraced).  Run this before merging
# anything that touches src/repro/core, src/repro/kernels or
# src/repro/runtime.
#
# BENCH_FULL=1 additionally refreshes BENCH_e2e_secure_fit.json at the
# full acceptance config (S=8, d=128, N=2e5; several minutes),
# BENCH_fault_overhead.json (supervision <= 2%/round gate) and
# BENCH_obs_overhead.json (tracing <= 2%/round gate).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== static privacy gate (taint verifier + protocol lints) =="
scripts/static_checks.sh

echo "== runtime privacy audit (ledger vs certified declassifications) =="
# every driver spec's executed declassification counts must reconcile
# with its gate-certified graph, and the deliberate extra-reveal
# self-test must be FLAGGED (exit 1 otherwise)
python -m repro.obs audit | tail -3

echo "== secure_overhead smoke (both backends) =="
python benchmarks/secure_overhead.py \
    --backend reference pallas \
    --sizes 10000 100000 --repeats 2 \
    --json BENCH_secure_overhead_smoke.json >/dev/null

python - <<'EOF'
import json, sys

rows = json.load(open("BENCH_secure_overhead_smoke.json"))
failures = []
for r in rows:
    if "max_abs_err" in r and not r["pass"]:
        failures.append(f"revealed sum inexact: {r}")
    if r.get("check", "").startswith("protection cost") and not r["pass"]:
        failures.append(f"superlinear scaling: {r}")
    if "speedup" in r:
        print(f"pallas protect+reveal speedup: {r['speedup']:.2f}x "
              f"(err delta {r['err_delta']:.3g})")
        if r["speedup"] < 1.5:
            failures.append(f"pallas speedup regressed below 1.5x: {r}")
        if r["err_delta"] != 0.0:
            failures.append(f"backends disagree on max_abs_err: {r}")
if failures:
    print("\n".join("FAIL: " + f for f in failures))
    sys.exit(1)
print("bench smoke OK")
EOF

echo "== e2e secure fit smoke (fused vs pre-fusion loop + coordinator) =="
# collective parity baseline: snapshot the committed smoke rows BEFORE
# the refresh overwrites them (the fresh run is compared against this
# below — bytes exact, wall clock within 3%)
E2E_BASELINE="$(mktemp)"
if [[ -f BENCH_e2e_secure_fit_smoke.json ]]; then
    cp BENCH_e2e_secure_fit_smoke.json "$E2E_BASELINE"
fi
python benchmarks/e2e_secure_fit.py --quick \
    --json BENCH_e2e_secure_fit_smoke.json >/dev/null

python - <<'EOF'
import json, sys

rows = json.load(open("BENCH_e2e_secure_fit_smoke.json"))
failures = []
saw_coord = False
for r in rows:
    if "path" in r:
        if not (r["converged"] and r["r2_vs_centralized"] > 0.999999):
            failures.append(f"secure vs centralized disagree: {r}")
    if r.get("check", "").startswith("fused speedup"):
        print(f"{r['check']}: {r['speedup']:.2f}x "
              f"(beta err {r['max_abs_err_vs_baseline']:.3g})")
        if not r["beta_identical_within_quantization"]:
            failures.append(f"fused beta outside quantization: {r}")
        # the loop_pallas row is informational; only gate the headline
        # baseline on speed (quick scale still has ample margin)
        if r["check"].endswith("pre_pr_loop") and r["speedup"] < 1.0:
            failures.append(f"fused slower than pre-fusion loop: {r}")
    if r.get("check", "").startswith("coordinator fused"):
        saw_coord = True
        print(f"{r['check']}: {r['round_speedup']:.2f}x/round "
              f"(round beta err {r['max_round_beta_err']:.3g})")
        if not r["pass"]:
            failures.append(f"coordinator gate failed: {r}")
if not saw_coord:
    failures.append("coordinator gate rows missing from e2e smoke")
if failures:
    print("\n".join("FAIL: " + f for f in failures))
    sys.exit(1)
print("e2e smoke OK")
EOF

echo "== collective parity (fresh rows vs committed smoke baselines) =="
# the SecureCollective refactor contract: the unified chain moves the
# SAME bytes per round (round_bytes is a static size model — any drift
# is a wire/telemetry change, not noise) and costs the same wall clock
# within 3% on the fused paths
E2E_BASELINE="$E2E_BASELINE" python - <<'EOF'
import json, os, sys

base_path = os.environ["E2E_BASELINE"]
if not os.path.exists(base_path) or os.path.getsize(base_path) == 0:
    print("collective parity SKIPPED: no committed baseline to compare")
    sys.exit(0)
base = {r["path"]: r for r in json.load(open(base_path))
        if isinstance(r, dict) and "path" in r}
fresh = {r["path"]: r for r in
         json.load(open("BENCH_e2e_secure_fit_smoke.json"))
         if isinstance(r, dict) and "path" in r}
GATED_WALL = ("fused", "coordinator_fused", "coordinator_fused_f32")
failures = []
for path, b in sorted(base.items()):
    f = fresh.get(path)
    if f is None:
        failures.append(f"path '{path}' missing from fresh smoke rows")
        continue
    if f["bytes_transmitted"] != b["bytes_transmitted"]:
        failures.append(
            f"{path}: per-round bytes moved "
            f"{b['bytes_transmitted']} -> {f['bytes_transmitted']} "
            "(round_bytes is static: this is a wire or telemetry change)")
    ratio = f["seconds_per_iter"] / b["seconds_per_iter"]
    gated = path in GATED_WALL
    print(f"  {path:<22} bytes {'==':>2}  wall {ratio:.3f}x"
          + ("" if gated else "  (informational)"))
    if gated and ratio > 1.03:
        failures.append(
            f"{path}: {ratio:.3f}x baseline wall clock (> 1.03x gate)")
if failures:
    print("\n".join("FAIL: " + f for f in failures))
    sys.exit(1)
print("collective parity OK")
EOF

echo "== secure_psum smoke (flat sharded wire vs per-leaf uint64 tree) =="
python benchmarks/secure_psum.py --quick >/dev/null

python - <<'EOF'
import json, sys

rows = json.load(open("BENCH_secure_psum_smoke.json"))
failures = []
saw_payload = False
for r in rows:
    if "path" in r and not r["pass"]:
        failures.append(f"secure_psum reveal inexact: {r}")
    if r.get("check") == "sharded payload vs per_leaf":
        saw_payload = True
        print(f"sharded payload ratio: {r['sharded_ratio']:.3f}x "
              f"(replicated {r['replicated_ratio']:.3f}x, "
              f"oracle err {r['max_abs_err_vs_oracle']:.3g})")
        if r["sharded_ratio"] > 0.55:
            failures.append(f"sharded payload above 0.55x per-leaf: {r}")
        if r["max_abs_err_vs_oracle"] != 0.0:
            failures.append(f"flat wire disagrees with per-leaf oracle: {r}")
if not saw_payload:
    failures.append("payload check row missing from secure_psum smoke")
if failures:
    print("\n".join("FAIL: " + f for f in failures))
    sys.exit(1)
print("secure_psum smoke OK")
EOF

echo "== lambda-path selection smoke (batched sweep vs sequential oracle) =="
python benchmarks/lambda_path.py --quick \
    --json BENCH_lambda_path_smoke.json >/dev/null

python - <<'EOF'
import json, sys

rows = json.load(open("BENCH_lambda_path_smoke.json"))
failures = []
saw_gate = False
for r in rows:
    if r.get("path") == "batched" and not r["pass"]:
        failures.append(f"batched sweep did not converge: {r}")
    if r.get("check", "").endswith("sequential_loop"):
        saw_gate = True
        print(f"{r['check']}: {r['speedup']:.2f}x "
              f"(fold beta err {r['max_fold_beta_err']:.3g})")
        if not r["pass"]:
            failures.append(f"lambda-path gate failed: {r}")
if not saw_gate:
    failures.append("lambda-path gate row missing from smoke output")
if failures:
    print("\n".join("FAIL: " + f for f in failures))
    sys.exit(1)
print("lambda-path smoke OK")
EOF

echo "== fault-overhead smoke (supervised rounds + chaos recovery) =="
python benchmarks/fault_overhead.py --quick >/dev/null

python - <<'EOF'
import json, sys

rows = json.load(open("BENCH_fault_overhead_smoke.json"))
failures = []
saw_sup, saw_sched = False, 0
for r in rows:
    if r.get("check") == "supervision overhead fault-free":
        saw_sup = True
        print(f"supervision overhead: {r['overhead_pct']:+.2f}%/round "
              f"(gate {r['gate_pct']:.0f}%, beta err "
              f"{r['beta_err_vs_bare']:.3g})")
        if not r["pass"]:
            failures.append(f"supervision overhead gate failed: {r}")
    if r.get("check") == "overflow_check callback overhead":
        print(f"overflow_check: {r['overhead_ms_per_round']:.2f}ms/round "
              f"({r['overhead_pct']:+.1f}% at smoke scale)")
        if not r["pass"]:
            failures.append(f"overflow_check perturbed the beta: {r}")
    if "schedule" in r:
        saw_sched += 1
        print(f"chaos {r['schedule']}: {r['retries']} retries, "
              f"{r['sim_backoff_seconds']:.0f}s backoff, "
              f"err {r['max_abs_err_vs_oracle']:.3g}")
        if not r["pass"]:
            failures.append(f"chaos schedule missed the oracle: {r}")
if not saw_sup:
    failures.append("supervision overhead row missing from fault smoke")
if saw_sched < 3:
    failures.append("chaos recovery rows missing from fault smoke")
if failures:
    print("\n".join("FAIL: " + f for f in failures))
    sys.exit(1)
print("fault-overhead smoke OK")
EOF

echo "== obs-overhead smoke (traced vs untraced drivers, bit parity) =="
python benchmarks/obs_overhead.py --quick >/dev/null

python - <<'EOF'
import json, sys

rows = json.load(open("BENCH_obs_overhead_smoke.json"))
failures = []
seen = set()
for r in rows:
    if "driver" not in r:
        continue
    seen.add(r["driver"])
    print(f"obs tracing [{r['driver']}]: {r['overhead_pct']:+.2f}%/round "
          f"(gate {r['gate_pct']:.0f}%, "
          f"bit-identical={r['beta_bit_identical']})")
    if not r["pass"]:
        failures.append(f"obs overhead gate failed: {r}")
if seen != {"loop", "fused", "scan"}:
    failures.append(f"driver rows missing from obs smoke: {seen}")
if failures:
    print("\n".join("FAIL: " + f for f in failures))
    sys.exit(1)
print("obs-overhead smoke OK")
EOF

echo "== multihost rounds smoke (scan residency + CPU-mesh latency) =="
python benchmarks/multihost_rounds.py --quick --real-kernels >/dev/null

python - <<'EOF'
import json, sys

rows = json.load(open("BENCH_multihost_rounds_smoke.json"))
failures = []
saw_scan, saw_flat, saw_2d, knob_rows = False, False, False, 0
for r in rows:
    if r.get("check") == "scan residency vs per-round fused":
        saw_scan = True
        print(f"scan residency: {r['speedup']:.2f}x measured, "
              f"{r['host_syncs_scan_path']} host sync/fit "
              f"(beta err {r['max_abs_err_vs_loop_oracle']:.3g}, "
              f"modeled {r['modeled_speedup_at_50ms_rtt']:.2f}x "
              f"at 50ms RTT)")
        if not r["pass"]:
            failures.append(f"scan residency gate failed: {r}")
    if r.get("check") == "round latency flat in institutions":
        saw_flat = True
        print(f"round latency S={r['s_low']} -> S={r['s_high']}: "
              f"{r['latency_ratio']:.3f}x (gate {r['gate']:.1f}x)")
        if not r["pass"]:
            failures.append(f"flat-in-S latency gate failed: {r}")
    if r.get("mesh") == "pod_share_2d":
        saw_2d = True
        if r["max_abs_err_vs_1d_wire"] != 0.0 or not r["pass"]:
            failures.append(f"2D distributed reveal != 1D wire: {r}")
    if r.get("check") == "real-kernel knobs":
        knob_rows += 1
        if not r["pass"]:
            failures.append(f"real-kernel knob invalid: {r}")
if not saw_scan:
    failures.append("scan residency gate row missing from multihost smoke")
if not saw_flat:
    failures.append("flat-in-S gate row missing from multihost smoke")
if not saw_2d:
    failures.append("2D mesh datapoint missing from multihost smoke")
if knob_rows < 4:
    failures.append("real-kernel knob rows missing from multihost smoke")
if failures:
    print("\n".join("FAIL: " + f for f in failures))
    sys.exit(1)
print("multihost rounds smoke OK")
EOF

if [[ "${BENCH_FULL:-0}" == "1" ]]; then
    echo "== e2e secure fit FULL (refreshes BENCH_e2e_secure_fit.json) =="
    python benchmarks/e2e_secure_fit.py >/dev/null
    python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_e2e_secure_fit.json"))
bad = [r for r in rows if r.get("check") == "fused speedup vs pre_pr_loop"
       and not r["pass"]]
# the coordinator acceptance: per-round parity on the default (f64) rung,
# >= 2x round time on the f32 rung at converged-beta parity; the rows
# must be PRESENT (a --driver secure_fit refresh would silently drop
# them and skip the gate)
coord = [r for r in rows
         if str(r.get("check", "")).startswith("coordinator fused")]
if not coord:
    print("FAIL: coordinator gate rows missing from BENCH_e2e_secure_fit.json")
    sys.exit(1)
bad += [r for r in coord if not r["pass"]]
if bad:
    print(f"FAIL: full e2e gate: {bad}")
    sys.exit(1)
print("full e2e gate OK")
EOF
    echo "== secure_psum FULL (refreshes BENCH_secure_psum.json) =="
    python benchmarks/secure_psum.py >/dev/null
    python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_secure_psum.json"))
bad = [r for r in rows if not r["pass"]]
wall = [r for r in rows if r.get("check") == "sharded wallclock vs per_leaf"]
if not wall:
    print("FAIL: wall-clock check row missing from BENCH_secure_psum.json")
    sys.exit(1)
if bad:
    print(f"FAIL: full secure_psum gate: {bad}")
    sys.exit(1)
print(f"full secure_psum gate OK ({wall[0]['speedup']:.2f}x vs per-leaf)")
EOF
    echo "== lambda-path FULL (refreshes BENCH_lambda_path.json) =="
    python benchmarks/lambda_path.py >/dev/null
    python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_lambda_path.json"))
bad = [r for r in rows
       if str(r.get("check", "")).endswith("sequential_loop")
       and not r["pass"]]
gate = [r for r in rows
        if str(r.get("check", "")).endswith("sequential_loop")]
if not gate:
    print("FAIL: lambda-path gate row missing from BENCH_lambda_path.json")
    sys.exit(1)
if bad:
    print(f"FAIL: full lambda-path gate (>= 3x + parity): {bad}")
    sys.exit(1)
print(f"full lambda-path gate OK ({gate[0]['speedup']:.2f}x)")
EOF
    echo "== fault-overhead FULL (refreshes BENCH_fault_overhead.json) =="
    python benchmarks/fault_overhead.py >/dev/null
    python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_fault_overhead.json"))
bad = [r for r in rows if ("check" in r or "schedule" in r)
       and not r["pass"]]
sup = [r for r in rows if r.get("check") == "supervision overhead fault-free"]
sched = [r for r in rows if "schedule" in r]
if not sup:
    print("FAIL: supervision row missing from BENCH_fault_overhead.json")
    sys.exit(1)
if len(sched) < 3:
    print("FAIL: recovery-latency rows missing from BENCH_fault_overhead.json")
    sys.exit(1)
if bad:
    # the acceptance gate: fault-free supervision <= 2%/round at the
    # full config, bit-identical beta, and every canned chaos schedule
    # recovering to the fault-free oracle
    print(f"FAIL: full fault-overhead gate: {bad}")
    sys.exit(1)
print(f"full fault-overhead gate OK "
      f"(supervision {sup[0]['overhead_pct']:+.2f}%/round, "
      f"{len(sched)} recovery schedules at oracle parity)")
EOF
    echo "== obs-overhead FULL (refreshes BENCH_obs_overhead.json) =="
    python benchmarks/obs_overhead.py >/dev/null
    python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_obs_overhead.json"))
gated = [r for r in rows if "driver" in r]
bad = [r for r in gated if not r["pass"]]
if len(gated) < 3:
    print("FAIL: driver rows missing from BENCH_obs_overhead.json")
    sys.exit(1)
if bad:
    # the acceptance gate: tracing <= 2%/round at the full config per
    # driver shape, traced beta BIT-identical to untraced
    print(f"FAIL: full obs-overhead gate: {bad}")
    sys.exit(1)
worst = max(r["overhead_pct"] for r in gated)
print(f"full obs-overhead gate OK (worst {worst:+.2f}%/round)")
EOF
    echo "== multihost rounds FULL (refreshes BENCH_multihost_rounds.json) =="
    python benchmarks/multihost_rounds.py --real-kernels >/dev/null
    python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_multihost_rounds.json"))
bad = [r for r in rows if ("check" in r or "mesh" in r)
       and "pass" in r and not r["pass"]]
scan = [r for r in rows
        if r.get("check") == "scan residency vs per-round fused"]
flat = [r for r in rows
        if r.get("check") == "round latency flat in institutions"]
if not scan or not flat:
    print("FAIL: gate rows missing from BENCH_multihost_rounds.json")
    sys.exit(1)
if bad:
    # the acceptance gate: one host sync per scanned fit at loop-oracle
    # beta parity (S=8, d=128, N=2e5), and CPU-mesh round latency at
    # S=256 within 1.5x of S=8
    print(f"FAIL: full multihost gate: {bad}")
    sys.exit(1)
print(f"full multihost gate OK "
      f"(scan {scan[0]['speedup']:.2f}x measured / "
      f"{scan[0]['modeled_speedup_at_50ms_rtt']:.2f}x at 50ms RTT, "
      f"S-latency ratio {flat[0]['latency_ratio']:.3f}x)")
EOF
fi
