#!/usr/bin/env bash
# Standing perf/correctness gate for the secure-aggregation hot path.
#
# Runs tier-1 tests, then a small-size secure_overhead smoke with BOTH
# backends and asserts (a) revealed-sum exactness on every row and (b) the
# fused Pallas pipeline is not slower than the reference oracle.  Run this
# before merging anything that touches src/repro/core or
# src/repro/kernels/shamir_*.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== secure_overhead smoke (both backends) =="
python benchmarks/secure_overhead.py \
    --backend reference pallas \
    --sizes 10000 100000 --repeats 2 \
    --json BENCH_secure_overhead_smoke.json >/dev/null

python - <<'EOF'
import json, sys

rows = json.load(open("BENCH_secure_overhead_smoke.json"))
failures = []
for r in rows:
    if "max_abs_err" in r and not r["pass"]:
        failures.append(f"revealed sum inexact: {r}")
    if r.get("check", "").startswith("protection cost") and not r["pass"]:
        failures.append(f"superlinear scaling: {r}")
    if "speedup" in r:
        print(f"pallas protect+reveal speedup: {r['speedup']:.2f}x "
              f"(err delta {r['err_delta']:.3g})")
        if r["speedup"] < 1.5:
            failures.append(f"pallas speedup regressed below 1.5x: {r}")
        if r["err_delta"] != 0.0:
            failures.append(f"backends disagree on max_abs_err: {r}")
if failures:
    print("\n".join("FAIL: " + f for f in failures))
    sys.exit(1)
print("bench smoke OK")
EOF
