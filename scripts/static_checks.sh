#!/usr/bin/env bash
# Standing static privacy gate: taint-verify every secure driver graph,
# run the protocol lints (one-host-sync-per-block, fixed-point headroom,
# mesh axes, Pallas VMEM knobs, obs purity — the tracer/ledger/metrics
# modules stay stdlib-only with zero callbacks or device
# materializers, collective boundary ownership), then confirm the
# deliberately-leaky fixtures are CAUGHT.  Pure tracing + AST +
# arithmetic — no kernel executes, so the whole gate runs in seconds.
#
# The RUNTIME half of the privacy story — reconciling executed
# declassifications against these certified graphs — is
# `python -m repro.obs audit` (bench_smoke runs it in quick mode).
#
#   scripts/static_checks.sh [--verbose] [--json] [--drivers SUBSTR]
#
# Exit status 0 iff every driver certifies clean AND every leak fixture
# produces an error finding.  See benchmarks/README.md ("Static checks")
# for what each pass proves and how to annotate an intentional
# declassification.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# repo hygiene: compiled bytecode must never be tracked (it is
# machine-specific noise and bloats every diff); .gitignore keeps new
# files out, this keeps anyone from force-adding them back
if git ls-files -- '*.pyc' '*__pycache__*' | grep -q .; then
    echo "static_checks: tracked Python bytecode found:" >&2
    git ls-files -- '*.pyc' '*__pycache__*' >&2
    echo "static_checks: run 'git rm -r --cached' on the paths above" >&2
    exit 1
fi

python -m repro.analysis "$@"
