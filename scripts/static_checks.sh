#!/usr/bin/env bash
# Standing static privacy gate: taint-verify every secure driver graph,
# run the protocol lints (one-host-sync-per-block, fixed-point headroom,
# mesh axes, Pallas VMEM knobs), then confirm the deliberately-leaky
# fixtures are CAUGHT.  Pure tracing + AST + arithmetic — no kernel
# executes, so the whole gate runs in seconds.
#
#   scripts/static_checks.sh [--verbose] [--json] [--drivers SUBSTR]
#
# Exit status 0 iff every driver certifies clean AND every leak fixture
# produces an error finding.  See benchmarks/README.md ("Static checks")
# for what each pass proves and how to annotate an intentional
# declassification.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m repro.analysis "$@"
