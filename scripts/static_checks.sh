#!/usr/bin/env bash
# Standing static privacy gate: taint-verify every secure driver graph,
# run the protocol lints (one-host-sync-per-block, fixed-point headroom,
# mesh axes, Pallas VMEM knobs, obs purity — the tracer/ledger/metrics
# modules stay stdlib-only with zero callbacks or device
# materializers), then confirm the deliberately-leaky fixtures are
# CAUGHT.  Pure tracing + AST + arithmetic — no kernel executes, so the
# whole gate runs in seconds.
#
# The RUNTIME half of the privacy story — reconciling executed
# declassifications against these certified graphs — is
# `python -m repro.obs audit` (bench_smoke runs it in quick mode).
#
#   scripts/static_checks.sh [--verbose] [--json] [--drivers SUBSTR]
#
# Exit status 0 iff every driver certifies clean AND every leak fixture
# produces an error finding.  See benchmarks/README.md ("Static checks")
# for what each pass proves and how to annotate an intentional
# declassification.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m repro.analysis "$@"
